"""Logical-axis sharding: one rules table drives 10 architectures x 2 meshes.

Every parameter/activation dimension carries a *logical* axis name
(``"embed"``, ``"heads"``, ``"vocab"``...).  A :class:`Sharder` binds those to
*mesh* axes through a rules table, with two production-grade twists:

* **divisibility-aware fallback** — a logical dim is only sharded if its size
  divides the mapped mesh-axes product (prefix fallback otherwise).  This is
  what lets `llama4`'s 40 heads, `granite-3`'s 49155 vocab or `grok`'s 8
  experts compile on a 16-way model axis without special-casing models.
* **no axis reuse within a tensor** — first dim to claim a mesh axis wins;
  later dims fall back or replicate.

Parallelism styles expressed purely through the table (DESIGN.md §5):
  FSDP   = "embed" -> data       (params + optimizer state sharded ZeRO-3)
  TP     = "heads"/"mlp"/"vocab" -> model  (Megatron)
  EP     = "expert" -> model
  SP     = "seq" -> model        (sequence parallelism, opt-in)
  DP     = "batch" -> (pod, data)
  CP     = "kv_seq" -> model     (sequence-sharded KV cache for decode)
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> tuple of mesh axes (in sharding-priority order)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),          # FSDP
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "rnn": ("model",),
    "inner": ("model",),         # xlstm up-projected dim
    "kv_seq": ("model",),        # KV-cache context parallelism (decode)
    "attn_seq": ("model",),      # context-parallel attention (heads % tp != 0)
    "seq": (),                   # -> ("model",) when SP enabled
    "layers": (),
    "conv": (),
    "stack": (),
}


class Sharder:
    """Binds logical axes to a concrete mesh; produces specs & constraints."""

    def __init__(self, mesh: Mesh, rules: Optional[dict] = None,
                 enable_sp: bool = False):
        self.mesh = mesh
        self.rules = dict(rules or DEFAULT_RULES)
        if enable_sp:
            self.rules["seq"] = ("model",)
        self.mesh_sizes = dict(zip(map(str, mesh.axis_names), mesh.devices.shape))

    # ------------------------------------------------------------------
    def axis_size(self, mesh_axis: str) -> int:
        return self.mesh_sizes.get(mesh_axis, 1)

    def logical_size(self, logical: str) -> int:
        """Product of mesh axes a logical name maps to (1 if unmapped)."""
        axes = [a for a in self.rules.get(logical, ()) if a in self.mesh_sizes]
        return int(math.prod(self.mesh_sizes[a] for a in axes)) if axes else 1

    @property
    def tp(self) -> int:
        return self.axis_size("model")

    @property
    def dp(self) -> int:
        return self.logical_size("batch")

    # ------------------------------------------------------------------
    def spec(self, shape: Sequence[int],
             axes: Sequence[Optional[str]]) -> P:
        """PartitionSpec for a tensor, divisibility-aware, no axis reuse."""
        assert len(shape) == len(axes), (shape, axes)
        used: set[str] = set()
        entries = []
        for dim, logical in zip(shape, axes):
            if logical is None:
                entries.append(None)
                continue
            mesh_axes = [a for a in self.rules.get(logical, ())
                         if a in self.mesh_sizes and a not in used]
            # prefix fallback: drop trailing axes until the product divides
            while mesh_axes and dim % math.prod(
                    self.mesh_sizes[a] for a in mesh_axes) != 0:
                mesh_axes.pop()
            if not mesh_axes:
                entries.append(None)
                continue
            used.update(mesh_axes)
            entries.append(tuple(mesh_axes) if len(mesh_axes) > 1 else mesh_axes[0])
        return P(*entries)

    def named(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def constraint(self, x, axes):
        """with_sharding_constraint by logical axes (shape-aware)."""
        return jax.lax.with_sharding_constraint(x, self.named(x.shape, axes))

    # ------------------------------------------------------------------
    def tree_shardings(self, shapes_tree, axes_tree):
        """NamedSharding pytree for (ShapeDtypeStruct tree, axes tree).

        ``axes_tree`` leaves are tuples of logical names; since tuples are
        pytree nodes we flatten it *up to* the shapes tree's structure.
        """
        shape_leaves, treedef = jax.tree.flatten(shapes_tree)
        axes_leaves = treedef.flatten_up_to(axes_tree)
        out = [self.named(s.shape, a) for s, a in zip(shape_leaves, axes_leaves)]
        return jax.tree.unflatten(treedef, out)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


def logical_to_spec(sharder: Sharder, shape, axes) -> P:
    return sharder.spec(shape, axes)
