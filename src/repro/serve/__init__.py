from .serve import (ServeConfig, make_prefill_step, make_decode_step,
                    cache_shardings, generate)

__all__ = ["ServeConfig", "make_prefill_step", "make_decode_step",
           "cache_shardings", "generate"]
