"""Serving layer: batched prefill + decode steps over sharded caches.

Decode-shape cells (``decode_32k``, ``long_500k``) lower ``serve_step`` — one
new token against a seq_len-deep cache.  Cache sharding comes from the same
logical-rules table as everything else: KV caches shard their sequence dim
over the model axis (context parallelism), recurrent states shard their
feature dim; batch shards over (pod, data).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel import Sharder


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048
    batch: int = 8
    cache_dtype: str = "bfloat16"
    temperature: float = 0.0             # 0 -> greedy


def cache_shardings(model, serve_cfg: ServeConfig, shd: Sharder):
    shapes = model.cache_shapes(serve_cfg.batch, serve_cfg.max_len,
                                serve_cfg.cache_dtype)
    axes = model.cache_axes()
    return shd.tree_shardings(shapes, axes)


def make_decode_step(model, shd: Sharder, serve_cfg: ServeConfig,
                     params_sh=None, donate_cache: bool = True):
    """jit'd decode_step(params, cache, batch) -> (logits, cache)."""
    cache_sh = cache_shardings(model, serve_cfg, shd)

    def step(params, cache, batch):
        return model.decode_step(params, cache, batch, shd)

    kw = dict(in_shardings=(params_sh, cache_sh, None),
              out_shardings=(None, cache_sh))
    if donate_cache:
        kw["donate_argnums"] = (1,)
    return jax.jit(step, **kw), cache_sh


def make_prefill_step(model, shd: Sharder, serve_cfg: ServeConfig,
                      params_sh=None):
    cache_sh = cache_shardings(model, serve_cfg, shd)

    def step(params, batch):
        return model.prefill(params, batch, shd, max_len=serve_cfg.max_len)

    return jax.jit(step, in_shardings=(params_sh, None),
                   out_shardings=(None, cache_sh)), cache_sh


def generate(model, params, prompts, shd: Sharder, *, steps: int = 16,
             max_len: int = 256, rng=None, temperature: float = 0.0):
    """Greedy/temperature batched generation (examples + integration tests)."""
    scfg = ServeConfig(max_len=max_len, batch=prompts.shape[0],
                       temperature=temperature)
    prefill, _ = make_prefill_step(model, shd, scfg)
    decode, _ = make_decode_step(model, shd, scfg, donate_cache=False)
    logits, cache = prefill(params, {"tokens": prompts})
    toks = []
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    def sample(logits, rng):
        if temperature > 0:
            return jax.random.categorical(rng, logits / temperature, axis=-1)
        return jnp.argmax(logits, axis=-1)

    tok = sample(logits.astype(jnp.float32), rng)
    toks.append(tok)
    for i in range(steps - 1):
        rng, k = jax.random.split(rng)
        logits, cache = decode(params, cache, {"tokens": tok[:, None]})
        tok = sample(logits[:, -1].astype(jnp.float32), k)
        toks.append(tok)
    return jnp.stack(toks, axis=1)
