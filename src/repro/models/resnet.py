"""ResNet-18 in pure JAX — the paper's image-classification evaluation app.

The paper (§4.2) profiles a data-parallel PyTorch ResNet-18 on 64x64
ImageNet-subset images and shows how gradient bucketing changes the
AllReduce call count (Table 3).  We reproduce that experiment with this
model + repro.train's bucketed DDP gradient sync + the monitor.

GroupNorm replaces BatchNorm (no cross-device stats; DDP does not sync BN
statistics either, so the communication profile is unchanged — DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Spec, init_params, param_axes, param_shapes

STAGES = (2, 2, 2, 2)                      # ResNet-18 basic blocks
WIDTHS = (64, 128, 256, 512)


def _conv_spec(cin, cout, k):
    return Spec((k, k, cin, cout), (None, None, None, "mlp"),
                scale=jnp.sqrt(2.0))


def _gn_spec(c):
    return {"scale": Spec((c,), ("mlp",), init="ones"),
            "bias": Spec((c,), ("mlp",), init="zeros")}


def resnet18_specs(num_classes: int = 200, in_ch: int = 3):
    specs = {
        "stem": {"conv": _conv_spec(in_ch, 64, 3), "gn": _gn_spec(64)},
        "stages": [],
        "fc": {"w": Spec((WIDTHS[-1], num_classes), (None, "mlp")),
               "b": Spec((num_classes,), ("mlp",), init="zeros")},
    }
    cin = 64
    stages = []
    for si, (n, w) in enumerate(zip(STAGES, WIDTHS)):
        blocks = []
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            block = {
                "conv1": _conv_spec(cin, w, 3), "gn1": _gn_spec(w),
                "conv2": _conv_spec(w, w, 3), "gn2": _gn_spec(w),
            }
            if stride != 1 or cin != w:
                block["proj"] = _conv_spec(cin, w, 1)
            blocks.append(block)
            cin = w
        stages.append(blocks)
    specs["stages"] = stages
    return specs


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn(x, p, groups=8):
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g).astype(jnp.float32)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    x = xg.reshape(b, h, w, c).astype(x.dtype)
    return x * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


def resnet18_apply(params, images, shd=None):
    """images: (B, H, W, 3) -> logits (B, num_classes)."""
    x = images
    x = _conv(x, params["stem"]["conv"].astype(x.dtype))
    x = jax.nn.relu(_gn(x, params["stem"]["gn"]))
    for si, blocks in enumerate(params["stages"]):
        for bi, bp in enumerate(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            r = x
            y = jax.nn.relu(_gn(_conv(x, bp["conv1"].astype(x.dtype), stride),
                                bp["gn1"]))
            y = _gn(_conv(y, bp["conv2"].astype(x.dtype)), bp["gn2"])
            if "proj" in bp:
                r = _conv(x, bp["proj"].astype(x.dtype), stride)
            x = jax.nn.relu(y + r)
    x = x.mean(axis=(1, 2))                                 # global avg pool
    return x @ params["fc"]["w"].astype(x.dtype) + params["fc"]["b"].astype(x.dtype)


def resnet18_loss(params, batch, shd=None):
    logits = resnet18_apply(params, batch["images"], shd).astype(jnp.float32)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return nll, {"acc": acc}


class ResNet18:
    def __init__(self, num_classes: int = 200):
        self.num_classes = num_classes

    def specs(self):
        return resnet18_specs(self.num_classes)

    def init(self, rng):
        return init_params(self.specs(), rng)

    def shapes(self):
        return param_shapes(self.specs())

    def axes(self):
        return param_axes(self.specs())

    def loss_fn(self, params, batch, shd=None, remat=None):
        return resnet18_loss(params, batch, shd)
