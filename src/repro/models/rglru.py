"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local-attention blocks.

Layer pattern (arXiv 2402.19427): repeating (recurrent, recurrent, local-attn)
— we scan over stacked superblocks of 3 plus a stacked tail of leftover
recurrent layers (26 = 3*8 + 2).

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  diagonal decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal linear recurrence is associative -> ``jax.lax.associative_scan``
(log-depth, parallelizes over time; this is the TPU-native answer to the
GPU kernel in the paper).  The Pallas kernel in ``repro.kernels.rglru`` is
the fused single-pass variant for the memory-bound regime.

Recurrent state for decode is O(1): h (B, d_rnn) + a (conv_width-1)-token
convolution buffer -> long_500k runs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention, layers
from .common import ModelConfig, Spec, init_params, param_axes, param_shapes, rms_norm

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
def rglru_spec(cfg: ModelConfig, stacked: int = 0) -> dict:
    d, dr, cw = cfg.d_model, cfg.d_rnn_, cfg.conv_width
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    return {
        # two input branches
        "w_gate": Spec(lead + (d, dr), lx + ("embed", "rnn")),     # gelu branch
        "w_rec_in": Spec(lead + (d, dr), lx + ("embed", "rnn")),   # conv branch
        # temporal depthwise conv
        "conv_w": Spec(lead + (cw, dr), lx + ("conv", "rnn"), scale=0.5),
        "conv_b": Spec(lead + (dr,), lx + ("rnn",), init="zeros"),
        # RG-LRU gates (dense, simplification of Griffin's block-diagonal)
        "w_a": Spec(lead + (dr, dr), lx + ("rnn", None)),
        "b_a": Spec(lead + (dr,), lx + ("rnn",), init="zeros"),
        "w_x": Spec(lead + (dr, dr), lx + ("rnn", None)),
        "b_x": Spec(lead + (dr,), lx + ("rnn",), init="zeros"),
        "lam": Spec(lead + (dr,), lx + ("rnn",), init="rglru_a"),
        # output projection
        "w_out": Spec(lead + (dr, d), lx + ("rnn", "embed")),
    }


def rec_layer_spec(cfg: ModelConfig, stacked: int = 0) -> dict:
    return {
        "norm1": layers.norm_spec(cfg, stacked=stacked),
        "rec": rglru_spec(cfg, stacked=stacked),
        "norm2": layers.norm_spec(cfg, stacked=stacked),
        "mlp": layers.mlp_spec(cfg, stacked=stacked),
    }


def attn_layer_spec(cfg: ModelConfig, stacked: int = 0) -> dict:
    return {
        "norm1": layers.norm_spec(cfg, stacked=stacked),
        "attn": attention.attn_spec(cfg, stacked=stacked),
        "norm2": layers.norm_spec(cfg, stacked=stacked),
        "mlp": layers.mlp_spec(cfg, stacked=stacked),
    }


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------
def rglru_scan(x, log_a):
    """h_t = exp(log_a_t) * h_{t-1} + x_t  via associative scan over axis 1.

    x, log_a: (B, S, Dr).  Returns h: (B, S, Dr) in fp32.
    """
    def combine(c1, c2):
        la1, x1 = c1
        la2, x2 = c2
        return la1 + la2, jnp.exp(la2) * x1 + x2

    la, h = jax.lax.associative_scan(combine, (log_a, x), axis=1)
    return h


def rglru_apply(p, x, cfg: ModelConfig, shd, state: Optional[dict] = None):
    """x: (B,S,Dr) conv output -> (h (B,S,Dr), new recurrent state h_last)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", xf, p["w_a"].astype(jnp.float32))
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", xf, p["w_x"].astype(jnp.float32))
                       + p["b_x"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    gated = shd.constraint(gated, ("batch", "seq", "rnn"))
    if state is not None and "h" in state:
        # fold carried state into the first step: x_0 += a_0 * h_prev
        gated = gated.at[:, 0].add(jnp.exp(log_a[:, 0]) * state["h"])
    h = rglru_scan(gated, log_a)
    return h, h[:, -1]


def temporal_conv(p, x, cfg: ModelConfig, prev: Optional[jax.Array] = None):
    """Causal depthwise conv width cw.  prev: (B, cw-1, Dr) decode buffer."""
    cw = cfg.conv_width
    w = p["conv_w"].astype(x.dtype)                     # (cw, Dr)
    if prev is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = prev.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+cw-1, Dr)
    out = sum(xp[:, j:j + x.shape[1]] * w[j] for j in range(cw))
    new_buf = xp[:, -(cw - 1):] if cw > 1 else None
    return out + p["conv_b"].astype(x.dtype), new_buf


def recurrent_block(p, x, cfg: ModelConfig, shd, state: Optional[dict] = None):
    """Griffin recurrent block.  x: (B,S,D) -> (out, new_state)."""
    dt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dk->bsk", x, p["w_gate"].astype(dt)))
    rec = jnp.einsum("bsd,dk->bsk", x, p["w_rec_in"].astype(dt))
    rec = shd.constraint(rec, ("batch", "seq", "rnn"))
    rec, conv_buf = temporal_conv(p, rec, cfg,
                                  None if state is None else state.get("conv"))
    h, h_last = rglru_apply(p, rec, cfg, shd, state)
    out = (gate.astype(jnp.float32) * h).astype(dt)
    out = jnp.einsum("bsk,kd->bsd", out, p["w_out"].astype(dt))
    new_state = None
    if state is not None:
        new_state = {"h": h_last,
                     "conv": conv_buf.astype(state["conv"].dtype)
                     if conv_buf is not None else state["conv"]}
    return out, new_state


def init_rec_state(cfg: ModelConfig, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.d_rnn_), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn_),
                          jnp.float32),
    }


def rec_state_axes():
    return {"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------
class GriffinLM:
    """RecurrentGemma-style hybrid LM: (rec, rec, local-attn) superblocks."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.attn_window > 0, "hybrid arch needs a local window"
        self.n_super = cfg.n_layers // 3
        self.n_tail = cfg.n_layers - 3 * self.n_super   # trailing rec layers

    # ------------------------------------------------------------------
    def specs(self):
        cfg, ns, nt = self.cfg, self.n_super, self.n_tail
        out = {
            "embed": layers.embed_spec(cfg),
            "super": {
                "rec1": rec_layer_spec(cfg, stacked=ns),
                "rec2": rec_layer_spec(cfg, stacked=ns),
                "attn": attn_layer_spec(cfg, stacked=ns),
            },
            "final_norm": layers.norm_spec(cfg),
            "head": layers.head_spec(cfg),
        }
        if nt:
            out["tail"] = rec_layer_spec(cfg, stacked=nt)
        return out

    def init(self, rng):
        return init_params(self.specs(), rng, self.cfg.param_dtype)

    def shapes(self):
        return param_shapes(self.specs(), self.cfg.param_dtype)

    def axes(self):
        return param_axes(self.specs())

    # ------------------------------------------------------------------
    def _rec_layer(self, p, x, shd, state=None):
        cfg = self.cfg
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        out, new_state = recurrent_block(p["rec"], h, cfg, shd, state)
        x = x + out
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + layers.mlp(p["mlp"], h, cfg, shd)
        return shd.constraint(x, ("batch", "seq", None)), new_state

    def _attn_layer(self, p, x, shd, cache=None):
        cfg = self.cfg
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        out, new_cache = attention.attention_block(p["attn"], h, cfg, shd,
                                                   cache=cache)
        x = x + out
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + layers.mlp(p["mlp"], h, cfg, shd)
        return shd.constraint(x, ("batch", "seq", None)), new_cache

    def _super_fwd(self, x, sp, shd):
        x, _ = self._rec_layer(sp["rec1"], x, shd)
        x, _ = self._rec_layer(sp["rec2"], x, shd)
        x, _ = self._attn_layer(sp["attn"], x, shd)
        return x

    def _trunk(self, params, x, shd, remat: Optional[str] = None):
        def body(carry, sp):
            f = jax.checkpoint(
                lambda c, s_: self._super_fwd(c, s_, shd),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            return f(carry, sp), None

        x, _ = jax.lax.scan(body, x, params["super"])
        if self.n_tail:
            def tail_body(carry, tp):
                y, _ = self._rec_layer(tp, carry, shd)
                return y, None
            x, _ = jax.lax.scan(tail_body, x, params["tail"])
        return x

    def loss_fn(self, params, batch, shd, remat: Optional[str] = None):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg, shd)
        x = self._trunk(params, x, shd, remat)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        loss = layers.chunked_lm_loss(params.get("head"), params["embed"], x,
                                      batch["labels"], cfg, shd)
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

    # ------------------------------------------------------------------
    # serving: stacked per-group states
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype: str = "bfloat16"):
        cfg, ns, nt = self.cfg, self.n_super, self.n_tail
        rec = init_rec_state(cfg, batch)
        kv = attention.init_kv_cache(cfg, batch, max_len, dtype=dtype)

        def stack(tree, n):
            return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype),
                                tree)

        return {
            "rec1": stack(rec, ns), "rec2": stack(rec, ns),
            "attn": {"k": stack(kv["k"], ns), "v": stack(kv["v"], ns)},
            "tail": stack(rec, nt) if nt else {},
            "len": jnp.zeros((), jnp.int32),
        }

    def cache_shapes(self, batch: int, max_len: int, dtype: str = "bfloat16"):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len, dtype))

    def cache_axes(self):
        ra = {"h": ("stack", "batch", "rnn"),
              "conv": ("stack", "batch", None, "rnn")}
        return {
            "rec1": ra, "rec2": ra,
            "attn": {"k": ("stack", "batch", "kv_seq", "kv_heads", None),
                     "v": ("stack", "batch", "kv_seq", "kv_heads", None)},
            "tail": ra if self.n_tail else {},
            "len": (),
        }

    def decode_step(self, params, cache, batch, shd):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg, shd)

        def body(x, sp, st):
            kv = {"k": st["attn_k"], "v": st["attn_v"], "len": cache["len"]}
            x, s1 = self._rec_layer(sp["rec1"], x, shd, state=st["rec1"])
            x, s2 = self._rec_layer(sp["rec2"], x, shd, state=st["rec2"])
            x, kv2 = self._attn_layer(sp["attn"], x, shd, cache=kv)
            return x, {"rec1": s1, "rec2": s2,
                       "attn_k": kv2["k"], "attn_v": kv2["v"]}

        def scan_body(carry, xs):
            sp, st = xs
            x, new = body(carry, sp, st)
            return x, new

        sts = {"rec1": cache["rec1"], "rec2": cache["rec2"],
               "attn_k": cache["attn"]["k"], "attn_v": cache["attn"]["v"]}
        x, new_sts = jax.lax.scan(scan_body, x, (params["super"], sts))
        new_cache = {
            "rec1": new_sts["rec1"], "rec2": new_sts["rec2"],
            "attn": {"k": new_sts["attn_k"], "v": new_sts["attn_v"]},
            "tail": cache.get("tail", {}),
            "len": cache["len"] + 1,
        }
        if self.n_tail:
            def tail_body(carry, xs):
                tp, st = xs
                y, ns = self._rec_layer(tp, carry, shd, state=st)
                return y, ns
            x, new_tail = jax.lax.scan(tail_body, x,
                                       (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = layers.lm_logits(params.get("head"), params["embed"], x,
                                  cfg, shd)
        return logits, new_cache

    def prefill(self, params, batch, shd, max_len: Optional[int] = None):
        """Sequence prefill producing decode states (rec h + ring kv)."""
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg, shd)
        b, s = batch["tokens"].shape
        max_len = max_len or s

        def super_fwd(x, sp):
            st = init_rec_state(cfg, b)
            kv0 = attention.init_kv_cache(cfg, b, max_len, dtype="bfloat16")
            x, s1 = self._rec_layer(sp["rec1"], x, shd,
                                    state={**st})
            x, s2 = self._rec_layer(sp["rec2"], x, shd, state={**st})
            x, kv = self._attn_layer(sp["attn"], x, shd, cache=kv0)
            return x, {"rec1": s1, "rec2": s2,
                       "attn_k": kv["k"], "attn_v": kv["v"]}

        def body(carry, sp):
            x, new = jax.checkpoint(
                super_fwd,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )(carry, sp)
            return x, new

        x, sts = jax.lax.scan(body, x, params["super"])
        cache = {"rec1": sts["rec1"], "rec2": sts["rec2"],
                 "attn": {"k": sts["attn_k"], "v": sts["attn_v"]},
                 "tail": {}, "len": jnp.full((), s, jnp.int32)}
        if self.n_tail:
            def tail_body(carry, tp):
                st = init_rec_state(cfg, b)
                y, ns = self._rec_layer(tp, carry, shd, state=st)
                return y, ns
            x, new_tail = jax.lax.scan(tail_body, x, params["tail"])
            cache["tail"] = new_tail
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = layers.lm_logits(params.get("head"), params["embed"], x,
                                  cfg, shd)
        return logits[:, 0], cache
