"""GNMT-style seq2seq (LSTM encoder/decoder + Luong attention) in JAX.

The paper's machine-translation evaluation app (§4.1): a data-parallel GNMT
whose training step the monitor profiles into Table 2 / Figs. 2-3.  This is
a faithful-at-communication-scale compact variant: stacked LSTM encoder,
attention decoder, shared training objective — the collective profile
(AllReduce of every gradient, Broadcast of initial params, AllGather of
metrics) matches the paper's table structure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Spec, init_params, param_axes, param_shapes


def _lstm_spec(d_in, d_h):
    return {"wx": Spec((d_in, 4 * d_h), (None, "mlp")),
            "wh": Spec((d_h, 4 * d_h), (None, "mlp")),
            "b": Spec((4 * d_h,), ("mlp",), init="zeros")}


def gnmt_specs(vocab: int = 32000, d: int = 512, layers: int = 2):
    return {
        "embed_src": Spec((vocab, d), ("vocab", "embed"), init="embed"),
        "embed_tgt": Spec((vocab, d), ("vocab", "embed"), init="embed"),
        "enc": [_lstm_spec(d, d) for _ in range(layers)],
        "dec": [_lstm_spec(d if i else 2 * d, d) for i in range(layers)],
        "attn_w": Spec((d, d), (None, "mlp")),
        "out": Spec((2 * d, vocab), (None, "vocab")),
    }


def _lstm_scan(p, xs, h0, c0):
    """xs: (B,S,Din) -> hs (B,S,Dh)."""
    def step(carry, x):
        h, c = carry
        z = x @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    (h, c), hs = jax.lax.scan(step, (h0, c0), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1), (h, c)


def gnmt_loss(params, batch, shd=None, remat=None):
    """batch: {"src": (B,S), "tgt": (B,T), "labels": (B,T)}."""
    src, tgt, labels = batch["src"], batch["tgt"], batch["labels"]
    b, s = src.shape
    d = params["embed_src"].shape[1]

    x = jnp.take(params["embed_src"], src, axis=0)
    h0 = jnp.zeros((b, d), x.dtype)
    enc = x
    for lp in params["enc"]:
        enc, _ = _lstm_scan(lp, enc, h0, h0)

    y = jnp.take(params["embed_tgt"], tgt, axis=0)
    # Luong attention per decoder step against encoder outputs
    keys = enc @ params["attn_w"]

    def dec_step(carry, yt):
        states = carry
        new_states = []
        inp = yt
        for li, lp in enumerate(params["dec"]):
            h, c = states[li]
            if li == 0:
                # attention context from previous top hidden state
                score = jnp.einsum("bd,bsd->bs", states[-1][0], keys)
                ctx = jnp.einsum("bs,bsd->bd", jax.nn.softmax(score), enc)
                inp = jnp.concatenate([yt, ctx], axis=-1)
            z = inp @ lp["wx"] + h @ lp["wh"] + lp["b"]
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            new_states.append((h, c))
            inp = h
        score = jnp.einsum("bd,bsd->bs", new_states[-1][0], keys)
        ctx = jnp.einsum("bs,bsd->bd", jax.nn.softmax(score), enc)
        out = jnp.concatenate([new_states[-1][0], ctx], axis=-1)
        return tuple(new_states), out

    states0 = tuple((h0, h0) for _ in params["dec"])
    _, outs = jax.lax.scan(dec_step, states0, y.swapaxes(0, 1))
    outs = outs.swapaxes(0, 1)                              # (B,T,2d)
    logits = (outs @ params["out"]).astype(jnp.float32)
    valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    loss = (nll * valid).sum() / jnp.maximum(valid.sum(), 1)
    return loss, {"xent": loss}


class GNMT:
    def __init__(self, vocab: int = 32000, d: int = 512, layers: int = 2):
        self.vocab, self.d, self.layers = vocab, d, layers

    def specs(self):
        return gnmt_specs(self.vocab, self.d, self.layers)

    def init(self, rng):
        return init_params(self.specs(), rng)

    def shapes(self):
        return param_shapes(self.specs())

    def axes(self):
        return param_axes(self.specs())

    def loss_fn(self, params, batch, shd=None, remat=None):
        return gnmt_loss(params, batch, shd, remat)
