"""Attention: GQA/MQA/MHA with RoPE, optional qk-norm, causal or sliding
window, chunked (flash-style) training path and KV-cache decode path.

The chunked path is the pure-JAX oracle of ``repro.kernels.flash_attention``;
the distributed models call :func:`repro.kernels.flash_attention.ops.attend`
which dispatches to the Pallas kernel on TPU and to this path elsewhere.

Sharding policy (computed from the mesh, see DESIGN.md §5): shard heads over
the model axis when divisible, else fall back to head_dim, else replicate.
The KV cache's sequence dim is sharded over the model axis for decode
(context parallelism) — that is what fits a 32k x 128-batch cache in HBM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, Spec, rms_norm
from .layers import apply_rope

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
def attn_spec(cfg: ModelConfig, stacked: int = 0,
              n_heads: Optional[int] = None,
              n_kv_heads: Optional[int] = None) -> dict:
    d, dh = cfg.d_model, cfg.dh
    nh = n_heads or cfg.n_heads
    nkv = n_kv_heads or cfg.n_kv_heads
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    spec = {
        "wq": Spec(lead + (d, nh * dh), lx + ("embed", "heads")),
        "wk": Spec(lead + (d, nkv * dh), lx + ("embed", "kv_heads")),
        "wv": Spec(lead + (d, nkv * dh), lx + ("embed", "kv_heads")),
        "wo": Spec(lead + (nh * dh, d), lx + ("heads", "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = Spec(lead + (dh,), lx + (None,), init="ones")
        spec["k_norm"] = Spec(lead + (dh,), lx + (None,), init="ones")
    return spec


def head_sharding_axes(cfg: ModelConfig, shd, nh: int, nkv: int):
    """(q_axes, kv_axes).  Training/prefill always use head sharding: when
    heads % tp != 0 the attention path zero-pads heads up to the next
    multiple of tp (llama4: 40 -> 48, +20% attention FLOPs) — measured to
    beat both alternatives:

    * head_dim sharding: contracting a sharded dh emits a score-matrix
      all-reduce per q-chunk per layer (llama4 train_4k: 2.7 PiB/step);
    * context-parallel (seq-sharded q): forces single-block scores,
      21 GiB/layer transient at 32k prefill (llama4: 64 GiB/dev peak).

    (EXPERIMENTS.md §Perf llama4 iterations 1 and 5.)
    """
    tp = shd.logical_size("heads")
    if tp > 1:
        q_ax = ("batch", "seq", "heads", None)
        kv_ax = ("batch", "seq",
                 "kv_heads" if nkv % tp == 0 else None, None)
    else:
        q_ax = ("batch", "seq", None, None)
        kv_ax = q_ax
    return q_ax, kv_ax


def pad_heads(x, nh_pad: int):
    """Zero-pad the head dim (axis 2) up to nh_pad."""
    b, s, nh, dh = x.shape
    if nh == nh_pad:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((b, s, nh_pad - nh, dh), x.dtype)], axis=2)


# ---------------------------------------------------------------------------
# core attention math (chunked, flash-style oracle)
#
# GQA is evaluated in repeat-KV MHA form: k/v are broadcast to the full head
# count BEFORE the einsums so every tensor keeps a single fused head dim.
# The grouped 5-D form (B,S,KVH,G,dh) shards KVH x G across the model axis
# only when both factors divide it — when they don't (granite: 8x4 over 16),
# GSPMD falls back to "involuntary full rematerialization" and emits a
# full all-gather of the score tensor per chunk (measured: 2.4 PB/step on
# granite-3-2b prefill_32k; EXPERIMENTS.md §Perf iteration 1).
# ---------------------------------------------------------------------------
def _expand_kv(k, h: int):
    """(B,S,KVH,dh) -> (B,S,H,dh) by broadcasting each kv head over its
    query group (free at the XLA level: a broadcast, not a copy)."""
    b, s, kvh, dh = k.shape
    if kvh == h:
        return k
    g = h // kvh
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, kvh, g, dh)).reshape(b, s, h, dh)


def _attend_block(qc, k, v, qpos, kpos, *, causal: bool, window: int):
    """qc: (B,cq,H,dh); k,v: (B,Skv,H,dh) (kv pre-expanded); global pos."""
    scale = qc.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bshd->bhqs",
                   (qc * scale).astype(jnp.float32), k.astype(jnp.float32))
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_chunk: int = 512, q_offset: int = 0):
    """Flash-style attention that never materializes (Sq,Skv) for all heads.

    q: (B,Sq,H,dh); k,v: (B,Skv,KVH,dh).  ``q_offset`` is the global position
    of q[0] (prefill continuation).  Returns (B,Sq,H,dh).
    """
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    kpos_full = jnp.arange(skv)
    if q_chunk >= sq:
        qpos = q_offset + jnp.arange(sq)
        return _attend_block(q, k, v, qpos, kpos_full, causal=causal,
                             window=window if window > 0 else 0)

    n_chunks = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qs = q.reshape(b, n_chunks, q_chunk, h, dh).swapaxes(0, 1)

    use_slice = window > 0 and skv > window + q_chunk

    def body(_, xs):
        qc, idx = xs
        qpos = q_offset + idx * q_chunk + jnp.arange(q_chunk)
        if use_slice:
            slice_len = window + q_chunk
            start = jnp.clip(q_offset + (idx + 1) * q_chunk - slice_len,
                             0, skv - slice_len)
            kc = jax.lax.dynamic_slice_in_dim(k, start, slice_len, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, slice_len, axis=1)
            kpos = start + jnp.arange(slice_len)
        else:
            kc, vc, kpos = k, v, kpos_full
        out = _attend_block(qc, kc, vc, qpos, kpos, causal=causal,
                            window=window)
        return None, out

    _, outs = jax.lax.scan(body, None,
                           (qs, jnp.arange(n_chunks)))
    return outs.swapaxes(0, 1).reshape(b, sq, h, dh)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     ring: bool = False):
    """Single-position decode: q (B,1,H,dh) over a (B,L,KVH,dh) cache.

    ``cache_len`` (scalar int) is the number of valid cache entries; the new
    token's k/v must already be written (at ``(cache_len-1) % L`` if ``ring``).
    A ring cache keeps only the last ``L`` (== window) positions — this is
    what bounds long_500k decode memory for windowed-attention archs.
    """
    b, _, h, dh = q.shape
    _, lmax, kvh, _ = k_cache.shape
    g = h // kvh
    scale = dh ** -0.5
    qg = q.reshape(b, kvh, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs",
                   (qg * scale).astype(jnp.float32),
                   k_cache.astype(jnp.float32))
    kpos = jnp.arange(lmax)
    if ring:
        # slot i holds absolute position cache_len-1-age, age=(cache_len-1-i)%L
        age = jnp.mod(cache_len - 1 - kpos, lmax)
        mask = age < cache_len  # slot written at least once
        if window > 0:
            mask &= age < window
    else:
        mask = kpos < cache_len
        if window > 0:
            mask &= kpos >= cache_len - window
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, dh)


# ---------------------------------------------------------------------------
# full attention block (projections + rope + attend + out-proj)
# ---------------------------------------------------------------------------
def attention_block(params, x, cfg: ModelConfig, shd, *,
                    positions=None, cache=None, window: Optional[int] = None,
                    n_heads: Optional[int] = None,
                    n_kv_heads: Optional[int] = None):
    """Returns (out, new_cache).  ``cache=None`` -> training/prefill w/o cache.

    cache = {"k": (B,L,KVH,dh), "v": ..., "len": int32 scalar} -> decode step.
    """
    from repro.kernels.flash_attention import ops as flash_ops

    b, s, d = x.shape
    nh = n_heads or cfg.n_heads
    nkv = n_kv_heads or cfg.n_kv_heads
    dh = cfg.dh
    win = cfg.attn_window if window is None else window
    dt = x.dtype
    q_ax, kv_ax = head_sharding_axes(cfg, shd, nh, nkv)

    q = jnp.einsum("bsd,dk->bsk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dk->bsk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dk->bsk", x, params["wv"].astype(dt))
    q = q.reshape(b, s, nh, dh)
    k = k.reshape(b, s, nkv, dh)
    v = v.reshape(b, s, nkv, dh)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if cache is None or s > 1:
        # training, or prefill (cache is filled with the sequence tail)
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_gqa, v_gqa = k, v              # unpadded GQA form for the cache
        # expand GQA kv to full heads BEFORE the sharding constraint so kv
        # activations shard over the model axis like q (a replicated kv
        # forces per-layer all-gathers; §Perf iteration 2).
        #
        # heads % tp != 0 has two viable schedules (§Perf llama4 it. 5-6):
        #   context-parallel (seq-sharded q, single score block) — cheapest
        #     when the per-device score block fits comfortably;
        #   head padding to the next multiple of tp — bounded-memory chunked
        #     flash path, +pad/nh attention FLOPs (llama4 32k: 21 GiB/layer
        #     scores make cp unusable).
        tp = shd.logical_size("heads")
        use_cp = False
        if tp > 1 and nh % tp != 0:
            b_loc = max(1, b // max(1, shd.dp))
            cp_score_bytes = b_loc * nh * (s // tp) * s * 4
            use_cp = cp_score_bytes < (2 << 30)
        if use_cp:
            q = shd.constraint(q, ("batch", "attn_seq", None, None))
            k = shd.constraint(_expand_kv(k, nh), ("batch", None, None, None))
            v = shd.constraint(_expand_kv(v, nh), ("batch", None, None, None))
            out = flash_ops.attend(q, k, v, causal=True, window=win,
                                   q_chunk=s)
            out = shd.constraint(out, ("batch", "attn_seq", None, None))
        else:
            nh_pad = -(-nh // tp) * tp if tp > 1 else nh
            q = shd.constraint(pad_heads(q, nh_pad), q_ax)
            k = shd.constraint(pad_heads(_expand_kv(k, nh), nh_pad), q_ax)
            v = shd.constraint(pad_heads(_expand_kv(v, nh), nh_pad), q_ax)
            out = flash_ops.attend(q, k, v, causal=True, window=win)
            out = shd.constraint(out, q_ax)[:, :, :nh]
        new_cache = None
        if cache is not None:
            lmax = cache["k"].shape[1]
            kc = k_gqa.astype(cache["k"].dtype)
            vc = v_gqa.astype(cache["v"].dtype)
            if s >= lmax:            # ring layout: slot j holds pos p, p%lmax==j
                kc, vc = kc[:, -lmax:], vc[:, -lmax:]
                kc = jnp.roll(kc, s % lmax, axis=1)
                vc = jnp.roll(vc, s % lmax, axis=1)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, 0, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, 0, axis=1)
            new_cache = {"k": kc, "v": vc,
                         "len": jnp.full((), s, jnp.int32)}
    else:
        pos = cache["len"]                                    # scalar int32
        lmax = cache["k"].shape[1]
        ring = win > 0 and lmax <= win                        # ring buffer
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
        q = shd.constraint(apply_rope(q, positions, cfg.rope_theta), q_ax)
        k = shd.constraint(apply_rope(k, positions, cfg.rope_theta), kv_ax)
        v = shd.constraint(v, kv_ax)
        slot = jnp.mod(pos, lmax) if ring else pos
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        out = decode_attention(q, k_cache, v_cache, pos + 1, window=win,
                               ring=ring)
        out = shd.constraint(out, q_ax)
        new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}

    out = jnp.einsum("bsk,kd->bsd",
                     out.reshape(b, -1, nh * dh).astype(dt),
                     params["wo"].astype(dt))
    return shd.constraint(out, ("batch", "seq", None)), new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                  n_kv_heads: Optional[int] = None, dtype: str = "bfloat16",
                  window: Optional[int] = None):
    nkv = n_kv_heads or cfg.n_kv_heads
    win = cfg.attn_window if window is None else window
    if win > 0:
        max_len = min(max_len, win)                           # ring buffer
    shape = (batch, max_len, nkv, cfg.dh)
    return {
        "k": jnp.zeros(shape, jnp.dtype(dtype)),
        "v": jnp.zeros(shape, jnp.dtype(dtype)),
        "len": jnp.zeros((), jnp.int32),
    }


def kv_cache_axes():
    return {
        "k": ("batch", "kv_seq", "kv_heads", None),
        "v": ("batch", "kv_seq", "kv_heads", None),
        "len": (),
    }
