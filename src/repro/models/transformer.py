"""Decoder-only transformer LM (dense + MoE), scan-over-layers.

Covers 8 of the 10 assigned architectures (dense, moe, vlm- and
audio-backbones).  Layers are stacked along a leading ``L`` dim and applied
with ``jax.lax.scan`` + per-layer ``jax.checkpoint`` — this keeps the HLO
O(1) in depth (compile time) and caps activation memory at one layer
(remat), both prerequisites for 314B-parameter dry-runs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention, layers, moe as moe_lib
from .common import ModelConfig, Spec, init_params, param_axes, param_shapes, rms_norm

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


class TransformerLM:
    """Pure-pytree decoder-only LM; all state explicit."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # parameter declaration
    # ------------------------------------------------------------------
    def specs(self):
        cfg = self.cfg
        L = cfg.n_layers
        layer = {
            "norm1": layers.norm_spec(cfg, stacked=L),
            "attn": attention.attn_spec(cfg, stacked=L),
            "norm2": layers.norm_spec(cfg, stacked=L),
        }
        if cfg.n_experts:
            layer["moe"] = moe_lib.moe_spec(cfg, stacked=L)
        else:
            layer["mlp"] = layers.mlp_spec(cfg, stacked=L)
        return {
            "embed": layers.embed_spec(cfg),
            "layers": layer,
            "final_norm": layers.norm_spec(cfg),
            "head": layers.head_spec(cfg),
        }

    def init(self, rng):
        return init_params(self.specs(), rng, self.cfg.param_dtype)

    def shapes(self):
        return param_shapes(self.specs(), self.cfg.param_dtype)

    def axes(self):
        return param_axes(self.specs())

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def _inputs(self, params, batch, shd):
        cfg = self.cfg
        if cfg.input_mode == "embeddings":
            x = batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
            x = shd.constraint(x, ("batch", "seq", None))
        else:
            x = layers.embed(params["embed"], batch["tokens"], cfg, shd)
        return x

    def _layer_fn(self, x, aux, lp, shd, cache=None):
        cfg = self.cfg
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        attn_out, new_cache = attention.attention_block(
            lp["attn"], h, cfg, shd, cache=cache)
        x = x + attn_out
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.n_experts:
            mo, a = moe_lib.moe_block(lp["moe"], h, cfg, shd)
            aux = aux + a
        else:
            mo = layers.mlp(lp["mlp"], h, cfg, shd)
        x = x + mo
        x = shd.constraint(x, ("batch", "seq", None))
        return x, aux, new_cache

    def _stack(self, params, x, shd, remat: Optional[str] = None):
        cfg = self.cfg

        def body(carry, lp):
            x, aux = carry
            x, aux, _ = self._layer_fn(x, aux, lp, shd)
            return (x, aux), None

        policy = REMAT_POLICIES.get(remat or "dots")
        if remat != "none":
            body = jax.checkpoint(body, policy=policy)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        return x, aux

    def loss_fn(self, params, batch, shd, remat: Optional[str] = None):
        cfg = self.cfg
        x = self._inputs(params, batch, shd)
        x, aux = self._stack(params, x, shd, remat)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        loss = layers.chunked_lm_loss(params.get("head"), params["embed"], x,
                                      batch["labels"], cfg, shd)
        return loss + aux, {"xent": loss, "aux": aux}

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype: str = "bfloat16"):
        cfg = self.cfg
        one = attention.init_kv_cache(cfg, batch, max_len, dtype=dtype)
        return {
            "k": jnp.broadcast_to(one["k"][None], (cfg.n_layers,) + one["k"].shape),
            "v": jnp.broadcast_to(one["v"][None], (cfg.n_layers,) + one["v"].shape),
            "len": one["len"],
        }

    def cache_shapes(self, batch: int, max_len: int, dtype: str = "bfloat16"):
        cfg = self.cfg
        win = cfg.attn_window
        L = min(max_len, win) if win > 0 else max_len
        shape = (cfg.n_layers, batch, L, cfg.n_kv_heads, cfg.dh)
        return {
            "k": jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)),
            "v": jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_axes(self):
        return {
            "k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "len": (),
        }

    def _stack_decode(self, params, x, cache, shd):
        """One-token step through all layers, scanning the stacked cache."""

        def body(carry, xs):
            x, aux = carry
            lp, kc, vc = xs
            layer_cache = {"k": kc, "v": vc, "len": cache["len"]}
            x, aux, new_cache = self._layer_fn(x, aux, lp, shd,
                                               cache=layer_cache)
            return (x, aux), (new_cache["k"], new_cache["v"])

        (x, _), (ks, vs) = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "len": cache["len"] + x.shape[1]}
        return x, new_cache

    def decode_step(self, params, cache, batch, shd):
        """batch: {"tokens": (B,1)} or {"embeds": (B,1,D)} -> (logits, cache)."""
        cfg = self.cfg
        x = self._inputs(params, batch, shd)
        x, new_cache = self._stack_decode(params, x, cache, shd)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = layers.lm_logits(params.get("head"), params["embed"], x,
                                  cfg, shd)
        return logits, new_cache

    def prefill(self, params, batch, shd, max_len: Optional[int] = None):
        """Full-sequence prefill; returns (last-token logits, filled cache)."""
        cfg = self.cfg
        x = self._inputs(params, batch, shd)
        s = x.shape[1]
        max_len = max_len or s

        def body(carry, xs):
            x, aux = carry
            lp = xs
            cache0 = attention.init_kv_cache(cfg, x.shape[0], max_len,
                                             dtype="bfloat16")
            x, aux, new_cache = self._layer_fn(x, aux, lp, shd, cache=cache0)
            return (x, aux), (new_cache["k"], new_cache["v"])

        body = jax.checkpoint(body, policy=REMAT_POLICIES["dots"])
        (x, _), (ks, vs) = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"])
        cache = {"k": ks, "v": vs, "len": jnp.full((), s, jnp.int32)}
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = layers.lm_logits(params.get("head"), params["embed"], x,
                                  cfg, shd)
        return logits[:, 0], cache
