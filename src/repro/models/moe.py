"""Mixture-of-Experts block: top-k router + capacity-bounded dense dispatch.

GShard/Switch-style einsum dispatch (the TPU-native formulation: dispatch is
a matmul, not a scatter, so it runs on the MXU and shards cleanly):

* tokens are grouped (``moe_group``) so the dispatch tensor is
  ``tokens x E x C_group`` with ``C_group = ceil(cf * k * group / E)`` —
  linear in group size, not sequence length;
* expert weights ``(E, d, f)`` shard E over the model axis when divisible
  (EP: llama4's 128 experts / 16), else the hidden dim f (TP-experts:
  grok's 8 experts);
* an auxiliary load-balancing loss and router z-loss are returned for the
  training objective.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig, Spec

MOE_GROUP = 512  # tokens per dispatch group


def moe_spec(cfg: ModelConfig, stacked: int = 0) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    return {
        "router": Spec(lead + (d, e), lx + ("embed", "expert"), scale=0.1),
        "wi": Spec(lead + (e, d, 2 * f), lx + ("expert", "embed", "mlp")),
        "wo": Spec(lead + (e, f, d), lx + ("expert", "mlp", "embed")),
    }


def group_capacity(cfg: ModelConfig, group: int = MOE_GROUP) -> int:
    c = math.ceil(cfg.capacity_factor * cfg.top_k * group / cfg.n_experts)
    return max(4, c)


def moe_block(params, x, cfg: ModelConfig, shd, group: int = MOE_GROUP):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar fp32)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    if s % group != 0:
        group = s                                          # tiny smoke configs
    ng = s // group
    c = group_capacity(cfg, group)

    xg = x.reshape(b, ng, group, d)
    router = params["router"].astype(jnp.float32)
    logits = jnp.einsum("bGsd,de->bGse", xg.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)                # (b,G,s,e)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (b,G,s,k)
    if k > 1:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    # choice-major position-in-expert (1st choices never dropped for 2nd)
    counts = jnp.zeros((b, ng, e), jnp.float32)
    dispatch = jnp.zeros((b, ng, group, e, c), jnp.float32)
    combine = jnp.zeros((b, ng, group, e, c), jnp.float32)
    sel_sum = jnp.zeros((b, ng, group, e), jnp.float32)
    for ki in range(k):
        sel_k = jax.nn.one_hot(gate_idx[..., ki], e, dtype=jnp.float32)
        pos_k = jnp.cumsum(sel_k, axis=2) - sel_k + counts[:, :, None, :]
        keep_k = sel_k * (pos_k < c)
        counts = counts + sel_k.sum(axis=2)
        oh = jax.nn.one_hot(pos_k.astype(jnp.int32), c,
                            dtype=jnp.float32) * keep_k[..., None]
        dispatch = dispatch + oh
        combine = combine + gate_vals[..., ki, None, None] * oh
        sel_sum = sel_sum + sel_k
    dispatch = shd.constraint(dispatch, ("batch", None, "seq", "expert", None))
    combine = shd.constraint(combine, ("batch", None, "seq", "expert", None))

    # expert computation
    wi = params["wi"].astype(dt)
    wo = params["wo"].astype(dt)
    xin = jnp.einsum("bGsec,bGsd->beGcd", dispatch.astype(dt), xg)
    xin = shd.constraint(xin, ("batch", "expert", None, None, None))
    h = jnp.einsum("beGcd,edF->beGcF", xin, wi)
    h = shd.constraint(h, ("batch", "expert", None, None, "mlp"))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("beGcf,efd->beGcd", h, wo)
    out = jnp.einsum("beGcd,bGsec->bGsd", out, combine.astype(dt))
    out = out.reshape(b, s, d)

    # aux losses: load balance (Switch) + router z-loss
    frac_tokens = jnp.mean(sel_sum, axis=(0, 1, 2))        # (e,)
    frac_probs = jnp.mean(probs, axis=(0, 1, 2))           # (e,)
    lb_loss = e * jnp.sum(frac_tokens * frac_probs)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = 0.01 * lb_loss + 0.001 * z_loss
    return shd.constraint(out, ("batch", "seq", None)), aux
