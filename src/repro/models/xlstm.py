"""xLSTM: alternating mLSTM (matrix memory) and sLSTM (scalar memory) blocks.

arXiv 2405.04517, adapted to TPU (DESIGN.md §2):

* **mLSTM** — training/prefill use the *parallel (quadratic) form*: the
  exponential-gated matrix-memory recurrence
      C_t = f_t C_{t-1} + i_t v_t k_t^T,   h_t = C_t q_t / max(|n_t q_t|, e^-m)
  is algebraically a decay-masked linear attention
      h_i = sum_j exp(b_i - b_j + itilde_j - m_i) (q_i.k_j) v_j / denom ,
  which we evaluate with the same chunked online-max scheme as flash
  attention — no per-step matrix state, so activation memory is O(chunk^2)
  and the 4k-token backward fits.  Decode uses the exact O(1) stabilized
  recurrence on (C, n, m).  Both paths agree to fp32 tolerance
  (tests/test_xlstm.py).
* **sLSTM** — inherently sequential (recurrent weights); two-level scan
  (outer chunks rematted) bounds backward memory.

Assignment: 48L, d_model 2048, 4 heads.  We alternate (mLSTM, sLSTM) 1:1 —
the paper's 1.3B uses an mLSTM-heavy ratio; noted in DESIGN.md §4.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import layers
from .common import ModelConfig, Spec, init_params, param_axes, param_shapes, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
def mlstm_spec(cfg: ModelConfig, stacked: int = 0) -> dict:
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    return {
        "norm": layers.norm_spec(cfg, stacked=stacked),
        "w_up": Spec(lead + (d, 2 * di), lx + ("embed", "inner")),
        "conv_w": Spec(lead + (cfg.conv_width, di), lx + ("conv", "inner"), scale=0.5),
        "conv_b": Spec(lead + (di,), lx + ("inner",), init="zeros"),
        "wq": Spec(lead + (di, di), lx + ("inner", None)),
        "wk": Spec(lead + (di, di), lx + ("inner", None)),
        "wv": Spec(lead + (di, di), lx + ("inner", None)),
        "w_i": Spec(lead + (di, nh), lx + ("inner", None), scale=0.1),
        "b_i": Spec(lead + (nh,), lx + (None,), init="zeros"),
        "w_f": Spec(lead + (di, nh), lx + ("inner", None), scale=0.1),
        "b_f": Spec(lead + (nh,), lx + (None,), init="ones"),
        "head_norm": Spec(lead + (di,), lx + ("inner",), init="ones"),
        "w_down": Spec(lead + (di, d), lx + ("inner", "embed")),
    }


def slstm_spec(cfg: ModelConfig, stacked: int = 0) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w_{g}"] = Spec(lead + (d, d), lx + ("embed", "inner"))
        gates[f"r_{g}"] = Spec(lead + (nh, dh, dh), lx + (None, "inner", None),
                               scale=0.5)
        gates[f"b_{g}"] = Spec(lead + (d,), lx + ("inner",),
                               init="ones" if g == "f" else "zeros")
    return {
        "norm": layers.norm_spec(cfg, stacked=stacked),
        **gates,
        "head_norm": Spec(lead + (d,), lx + ("inner",), init="ones"),
        "w_out": Spec(lead + (d, d), lx + ("inner", "embed")),
    }


# ---------------------------------------------------------------------------
# mLSTM parallel (quadratic, chunked) form
# ---------------------------------------------------------------------------
def _mlstm_gates(p, xc):
    """xc: (B,S,di) conv branch -> (log_f, itilde): (B,S,nh) fp32."""
    xf = xc.astype(jnp.float32)
    itilde = jnp.einsum("bsd,dh->bsh", xf, p["w_i"].astype(jnp.float32)) \
        + p["b_i"].astype(jnp.float32)
    ftilde = jnp.einsum("bsd,dh->bsh", xf, p["w_f"].astype(jnp.float32)) \
        + p["b_f"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(ftilde)
    return log_f, itilde


def mlstm_parallel(q, k, v, log_f, itilde, *, chunk: int = 256):
    """Decay-masked linear attention (the mLSTM parallel form).

    q,k,v: (B,S,nh,dh); log_f,itilde: (B,S,nh).  Returns (B,S,nh,dh) fp32.
    """
    b, s, nh, dh = q.shape
    scale = dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    bcum = jnp.cumsum(log_f, axis=1)                       # (B,S,nh)

    if s <= chunk:
        return _mlstm_block(qf, kf, vf, bcum, itilde)

    assert s % chunk == 0
    nc = s // chunk

    def reshape(x):
        return x.reshape((b, nc, chunk) + x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, bs_, is_ = map(reshape, (qf, kf, vf, bcum, itilde))

    def body(_, xs):
        qi, bi, ii, ci = xs
        # chunk ci attends to kv chunks 0..ci (masked inside)
        num = jnp.zeros((b, chunk, nh, dh), jnp.float32)
        den = jnp.zeros((b, chunk, nh), jnp.float32)
        m = jnp.full((b, chunk, nh), NEG_INF)

        def inner(carry, ys):
            num, den, m = carry
            kj, vj, bj, ij, cj = ys
            d_ = bi[:, :, None, :] - bj[:, None, :, :] + ij[:, None, :, :]
            mask = (cj < ci) | ((cj == ci)
                                & (jnp.arange(chunk)[None, :, None, None]
                                   >= jnp.arange(chunk)[None, None, :, None]))
            valid = (cj <= ci)
            d_ = jnp.where(mask & valid, d_, NEG_INF)      # (B,cq,ck,nh)
            m_new = jnp.maximum(m, d_.max(axis=2))
            alpha = jnp.exp(m - m_new)
            sc = jnp.einsum("bqhd,bkhd->bqkh", qi, kj) * jnp.exp(
                d_ - m_new[:, :, None, :])
            num = num * alpha[..., None] + jnp.einsum("bqkh,bkhd->bqhd", sc, vj)
            den = den * alpha + sc.sum(axis=2)
            return (num, den, m_new), None

        cidx = jnp.arange(nc)
        (num, den, m), _ = jax.lax.scan(
            inner, (num, den, m), (ks, vs, bs_, is_, cidx))
        out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        return None, out

    _, outs = jax.lax.scan(body, None, (qs, bs_, is_, jnp.arange(nc)))
    return outs.swapaxes(0, 1).reshape(b, s, nh, dh)


def _mlstm_block(qf, kf, vf, bcum, itilde):
    """Single-block quadratic evaluation (S small)."""
    d_ = bcum[:, :, None, :] - bcum[:, None, :, :] + itilde[:, None, :, :]
    s = qf.shape[1]
    mask = jnp.tril(jnp.ones((s, s), bool))
    d_ = jnp.where(mask[None, :, :, None], d_, NEG_INF)
    m = d_.max(axis=2)                                     # (B,S,nh)
    sc = jnp.einsum("bqhd,bkhd->bqkh", qf, kf) * jnp.exp(d_ - m[:, :, None, :])
    num = jnp.einsum("bqkh,bkhd->bqhd", sc, vf)
    den = sc.sum(axis=2)
    return num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]


def mlstm_decode_step(q, k, v, log_f, itilde, state):
    """Exact O(1) stabilized recurrence.  q,k,v: (B,nh,dh); gates: (B,nh).

    state: {"C": (B,nh,dh,dh), "n": (B,nh,dh), "m": (B,nh)}.
    """
    scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32) * scale
    m_new = jnp.maximum(log_f + state["m"], itilde)
    fprime = jnp.exp(log_f + state["m"] - m_new)
    iprime = jnp.exp(itilde - m_new)
    C = state["C"] * fprime[..., None, None] + iprime[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", v.astype(jnp.float32), k.astype(jnp.float32))
    n = state["n"] * fprime[..., None] + iprime[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", C, qf)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return h, {"C": C, "n": n, "m": m_new}


def mlstm_final_state(k, v, log_f, itilde):
    """State after consuming a sequence (prefill).  k,v: (B,S,nh,dh)."""
    bcum = jnp.cumsum(log_f, axis=1)
    btot = bcum[:, -1]                                      # (B,nh)
    d_ = btot[:, None] - bcum + itilde                      # (B,S,nh)
    m = d_.max(axis=1)                                      # (B,nh)
    w = jnp.exp(d_ - m[:, None])                            # (B,S,nh)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = jnp.einsum("bsh,bshd,bshe->bhde", w, vf, kf)
    n = jnp.einsum("bsh,bshd->bhd", w, kf)
    return {"C": C, "n": n, "m": m}


def mlstm_block_apply(p, x, cfg: ModelConfig, shd,
                      state: Optional[dict] = None):
    """Full mLSTM residual block.  x: (B,S,D)."""
    from .rglru import temporal_conv
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    dh = di // nh
    dt = x.dtype
    b, s, _ = x.shape

    h = rms_norm(x, p["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,dk->bsk", h, p["w_up"].astype(dt))
    up = shd.constraint(up, ("batch", "seq", "inner"))
    xm, z = jnp.split(up, 2, axis=-1)
    conv_buf = None if state is None else state.get("conv")
    xc, new_conv = temporal_conv(p, xm, cfg, conv_buf)
    xc = jax.nn.silu(xc)

    q = jnp.einsum("bsd,dk->bsk", xc, p["wq"].astype(dt)).reshape(b, s, nh, dh)
    k = jnp.einsum("bsd,dk->bsk", xc, p["wk"].astype(dt)).reshape(b, s, nh, dh)
    v = jnp.einsum("bsd,dk->bsk", xm, p["wv"].astype(dt)).reshape(b, s, nh, dh)
    log_f, itilde = _mlstm_gates(p, xc)

    new_state = None
    if state is None:
        ht = mlstm_parallel(q, k, v, log_f, itilde, chunk=cfg.mlstm_chunk)
    elif s == 1:
        hd, mstate = mlstm_decode_step(
            q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], itilde[:, 0],
            {"C": state["C"], "n": state["n"], "m": state["m"]})
        ht = hd[:, None]
        new_state = {**mstate, "conv": new_conv.astype(state["conv"].dtype)}
    else:  # prefill: parallel outputs + final recurrent state
        ht = mlstm_parallel(q, k, v, log_f, itilde, chunk=cfg.mlstm_chunk)
        mstate = mlstm_final_state(k, v, log_f, itilde)
        new_state = {**mstate, "conv": new_conv.astype(state["conv"].dtype)}

    ht = ht.reshape(b, s, di)
    ht = rms_norm(ht.astype(dt), p["head_norm"], cfg.norm_eps)
    out = ht * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", out, p["w_down"].astype(dt))
    return x + shd.constraint(out, ("batch", "seq", None)), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    di = 2 * cfg.d_model
    nh = cfg.n_heads
    dh = di // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), jnp.float32),
    }


def mlstm_state_axes():
    return {"C": ("batch", None, "inner", None),
            "n": ("batch", None, "inner"),
            "m": ("batch", None),
            "conv": ("batch", None, "inner")}


# ---------------------------------------------------------------------------
# sLSTM (sequential; two-level rematted scan)
# ---------------------------------------------------------------------------
def _slstm_step(p, carry, xg, nh, dh):
    """One sLSTM step.  carry: (c,n,m,hprev) each (B,d); xg: dict of (B,d)."""
    c, n, m, hp = carry
    b = xg["z"].shape[0]
    hph = hp.reshape(b, nh, dh)

    def rec(g):
        return jnp.einsum("bhd,hde->bhe", hph,
                          p[f"r_{g}"].astype(jnp.float32)).reshape(b, nh * dh)

    zt = jnp.tanh(xg["z"] + rec("z"))
    it = xg["i"] + rec("i")
    ft = xg["f"] + rec("f")
    ot = jax.nn.sigmoid(xg["o"] + rec("o"))
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    fprime = jnp.exp(log_f + m - m_new)
    iprime = jnp.exp(it - m_new)
    c_new = fprime * c + iprime * zt
    n_new = fprime * n + iprime
    h = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h), h


def slstm_apply(p, x, cfg: ModelConfig, shd, state: Optional[dict] = None,
                chunk: int = 256):
    """x: (B,S,D) -> (out, new_state).  Sequential over time."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    b, s, _ = x.shape
    h_in = rms_norm(x, p["norm"], cfg.norm_eps)
    xf = h_in.astype(jnp.float32)
    xg = {g: jnp.einsum("bsd,dk->bsk", xf, p[f"w_{g}"].astype(jnp.float32))
          + p[f"b_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    if state is None:
        carry = (jnp.zeros((b, d)), jnp.zeros((b, d)),
                 jnp.full((b, d), -1e30), jnp.zeros((b, d)))
    else:
        carry = (state["c"], state["n"], state["m"], state["h"])

    def step(carry, xs):
        return _slstm_step(p, carry, xs, nh, dh)

    if s == 1:
        carry, hs = step(carry, {g: xg[g][:, 0] for g in xg})
        hs = hs[:, None]
    else:
        cs = chunk if s % chunk == 0 and s > chunk else s

        def outer(carry, xs):
            def inner(c2, ys):
                return step(c2, ys)
            carry, hs = jax.lax.scan(inner, carry, xs)
            return carry, hs

        xs = {g: xg[g].reshape(b, s // cs, cs, d).transpose(1, 2, 0, 3)
              for g in xg}
        outer_r = jax.checkpoint(outer)
        carry, hs = jax.lax.scan(outer_r, carry, xs)       # (nc, cs, B, d)
        hs = hs.reshape(s, b, d).transpose(1, 0, 2)

    new_state = None
    if state is not None:
        new_state = {"c": carry[0], "n": carry[1], "m": carry[2],
                     "h": carry[3]}
    dt = x.dtype
    hs = rms_norm(hs.astype(dt), p["head_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,dk->bsk", hs, p["w_out"].astype(dt))
    return x + shd.constraint(out, ("batch", "seq", None)), new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d)), "n": jnp.zeros((batch, d)),
            "m": jnp.full((batch, d), -1e30), "h": jnp.zeros((batch, d))}


def slstm_state_axes():
    a = ("batch", "inner")
    return {"c": a, "n": a, "m": a, "h": a}


# ---------------------------------------------------------------------------
# the model: scan over (mLSTM, sLSTM) superblocks
# ---------------------------------------------------------------------------
class XLSTMLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.n_layers % 2 == 0
        self.n_super = cfg.n_layers // 2

    def specs(self):
        cfg, ns = self.cfg, self.n_super
        return {
            "embed": layers.embed_spec(cfg),
            "super": {
                "mlstm": mlstm_spec(cfg, stacked=ns),
                "slstm": slstm_spec(cfg, stacked=ns),
            },
            "final_norm": layers.norm_spec(cfg),
            "head": layers.head_spec(cfg),
        }

    def init(self, rng):
        return init_params(self.specs(), rng, self.cfg.param_dtype)

    def shapes(self):
        return param_shapes(self.specs(), self.cfg.param_dtype)

    def axes(self):
        return param_axes(self.specs())

    def _super_fwd(self, x, sp, shd):
        x, _ = mlstm_block_apply(sp["mlstm"], x, self.cfg, shd)
        x, _ = slstm_apply(sp["slstm"], x, self.cfg, shd)
        return x

    def loss_fn(self, params, batch, shd, remat: Optional[str] = None):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg, shd)

        def body(carry, sp):
            f = jax.checkpoint(
                lambda c, s_: self._super_fwd(c, s_, shd),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            return f(carry, sp), None

        x, _ = jax.lax.scan(body, x, params["super"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        loss = layers.chunked_lm_loss(params.get("head"), params["embed"], x,
                                      batch["labels"], cfg, shd)
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

    # -- serving -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype: str = "bfloat16"):
        cfg, ns = self.cfg, self.n_super

        def stack(tree):
            return jax.tree.map(lambda a: jnp.zeros((ns,) + a.shape, a.dtype),
                                tree)

        return {"mlstm": stack(init_mlstm_state(cfg, batch)),
                "slstm": stack(init_slstm_state(cfg, batch)),
                "len": jnp.zeros((), jnp.int32)}

    def cache_shapes(self, batch: int, max_len: int, dtype: str = "bfloat16"):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len, dtype))

    def cache_axes(self):
        st = lambda d: {k: ("stack",) + v for k, v in d.items()}
        return {"mlstm": st(mlstm_state_axes()),
                "slstm": st(slstm_state_axes()), "len": ()}

    def _step_or_prefill(self, params, cache, batch, shd, prefill: bool):
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], cfg, shd)
        b = x.shape[0]

        def body(carry, xs):
            sp, st = xs
            if prefill:
                mst = init_mlstm_state(cfg, b)
                sst = init_slstm_state(cfg, b)
            else:
                mst = st["m_"]
                sst = st["s_"]
            x1, new_m = mlstm_block_apply(sp["mlstm"], carry, cfg, shd,
                                          state=mst)
            x2, new_s = slstm_apply(sp["slstm"], x1, cfg, shd, state=sst)
            return x2, {"m_": new_m, "s_": new_s}

        sts = {"m_": cache["mlstm"], "s_": cache["slstm"]}
        x, new = jax.lax.scan(body, x, (params["super"], sts))
        new_cache = {"mlstm": new["m_"], "slstm": new["s_"],
                     "len": cache["len"] + x.shape[1]}
        x = rms_norm(x[:, -1:] if prefill else x, params["final_norm"],
                     cfg.norm_eps)
        logits = layers.lm_logits(params.get("head"), params["embed"], x,
                                  cfg, shd)
        return (logits[:, 0] if prefill else logits), new_cache

    def decode_step(self, params, cache, batch, shd):
        return self._step_or_prefill(params, cache, batch, shd, prefill=False)

    def prefill(self, params, batch, shd, max_len: Optional[int] = None):
        cache = self.init_cache(batch["tokens"].shape[0], 0)
        return self._step_or_prefill(params, cache, batch, shd, prefill=True)
