"""Model factory: one entry point for all assigned architectures."""
from __future__ import annotations

from .common import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.family == "ssm":
        from .xlstm import XLSTMLM
        return XLSTMLM(cfg)
    if cfg.family == "hybrid":
        from .rglru import GriffinLM
        return GriffinLM(cfg)
    # dense / moe / vlm / audio all run on the transformer backbone
    from .transformer import TransformerLM
    return TransformerLM(cfg)
