"""Shared neural layers: embeddings, projections, MLPs, chunked LM loss.

All layers take ``(params, x, ...)`` plus the :class:`~repro.parallel.Sharder`
for activation constraints, and are written against the declarative
:class:`~repro.models.common.Spec` system.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, Spec, rms_norm


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
def embed_spec(cfg: ModelConfig) -> dict:
    return {"tok": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                        init="embed", scale=1.0)}


def head_spec(cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {"w": Spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))}


def mlp_spec(cfg: ModelConfig, stacked: int = 0) -> dict:
    """GeGLU / SwiGLU MLP: gate+up projections and down projection."""
    d, f = cfg.d_model, cfg.d_ff
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    return {
        "wi": Spec(lead + (d, 2 * f), lax_ + ("embed", "mlp")),
        "wo": Spec(lead + (f, d), lax_ + ("mlp", "embed")),
    }


def norm_spec(cfg: ModelConfig, stacked: int = 0, dim: Optional[int] = None) -> Spec:
    d = dim or cfg.d_model
    if stacked:
        return Spec((stacked, d), ("layers", None), init="ones")
    return Spec((d,), (None,), init="ones")


# ---------------------------------------------------------------------------
# applies
# ---------------------------------------------------------------------------
def embed(params, tokens, cfg: ModelConfig, shd):
    """Token embedding lookup with a vocab-sharded table."""
    w = params["tok"].astype(jnp.dtype(cfg.compute_dtype))
    out = jnp.take(w, tokens, axis=0)
    return shd.constraint(out, ("batch", "seq", None))


def mlp(params, x, cfg: ModelConfig, shd):
    """SwiGLU MLP; hidden dim sharded over the model axis (TP)."""
    dt = x.dtype
    wi = params["wi"].astype(dt)
    wo = params["wo"].astype(dt)
    h = jnp.einsum("bsd,dF->bsF", x, wi)
    h = shd.constraint(h, ("batch", "seq", "mlp"))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("bsf,fd->bsd", h, wo)
    return shd.constraint(out, ("batch", "seq", None))


def lm_logits(params_head, params_embed, h, cfg: ModelConfig, shd):
    """Final logits; vocab sharded over model axis."""
    dt = h.dtype
    if cfg.tie_embeddings:
        w = params_embed["tok"].astype(dt).T
    else:
        w = params_head["w"].astype(dt)
    logits = jnp.einsum("bsd,dv->bsv", h, w)
    return shd.constraint(logits, ("batch", "seq", "vocab"))


def chunked_lm_loss(params_head, params_embed, h, labels, cfg: ModelConfig,
                    shd, chunk: int = 512):
    """Cross-entropy without materializing (B,S,V) logits.

    Scans over sequence chunks; per chunk computes logits -> fp32 CE.  With
    remat this caps logits memory at (B, chunk, V/tp) — the difference between
    fitting and OOM for 131k-vocab models at 4k sequence.
    """
    b, s, d = h.shape
    if s % chunk != 0:
        chunk = s  # degenerate fallback (smoke tests with tiny seq)
    n_chunks = s // chunk
    if cfg.tie_embeddings:
        w = params_embed["tok"].T
    else:
        w = params_head["w"]
    w = w.astype(h.dtype)

    hc = h.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)        # (C,B,chunk,d)
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        hx, lx = xs
        logits = jnp.einsum("bsd,dv->bsv", hx, w)
        logits = shd.constraint(logits, ("batch", "seq", "vocab"))
        logits = logits.astype(jnp.float32)
        valid = lx >= 0
        lab = jnp.maximum(lx, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = ((logz - gold) * valid).sum()
        return (carry[0] + nll, carry[1] + valid.sum()), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.int32)), (hc, lc))
    return nll / jnp.maximum(cnt, 1)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                               # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B,S,dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


__all__ = [
    "embed_spec", "head_spec", "mlp_spec", "norm_spec",
    "embed", "mlp", "lm_logits", "chunked_lm_loss",
    "apply_rope", "rms_norm",
]
