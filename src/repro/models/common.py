"""Model substrate foundations: configs and declarative parameter specs.

Models declare their parameters as a pytree of :class:`Spec` (shape + logical
sharding axes + initializer).  From that single declaration we derive:

* ``init_params``    — materialized parameters (reduced configs, smoke tests),
* ``param_shapes``   — ``ShapeDtypeStruct`` stand-ins (full-config dry-runs,
  no allocation),
* ``param_axes``     — logical-axes pytree consumed by
  :mod:`repro.parallel.sharding` to produce ``NamedSharding``.

Keeping shapes/axes/init in one object is what keeps 10 architectures x 2
meshes coherent.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # attention variants
    qk_norm: bool = False
    attn_window: int = 0             # 0 = full causal; >0 = sliding window
    rope_theta: float = 10000.0
    # layer pattern, cycled over depth: "attn" | "mlstm" | "slstm" | "rec"
    block_pattern: tuple[str, ...] = ("attn",)
    # modality frontend: "tokens" (LM) | "embeddings" (stubbed vlm/audio)
    input_mode: str = "tokens"
    tie_embeddings: bool = False
    # recurrent blocks
    conv_width: int = 4              # RG-LRU temporal conv width
    d_rnn: int = 0                   # RG-LRU recurrence width (0 -> d_model)
    mlstm_chunk: int = 256           # chunkwise-parallel mLSTM chunk length
    norm_eps: float = 1e-6
    # dtypes (strings to keep config hashable/serializable)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # long_500k eligibility (sub-quadratic attention / recurrent state)
    subquadratic: bool = False
    # optimizer preset for this scale ("adamw" | "adafactor")
    optimizer: str = "adamw"
    # optimizer state dtype (large models use bf16 moments to fit HBM)
    opt_state_dtype: str = "float32"

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_rnn_(self) -> int:
        return self.d_rnn or self.d_model

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def n_params(self) -> float:
        """Approximate total parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        dh, nh, nkv = self.dh, self.n_heads, self.n_kv_heads
        per_block = {}
        attn = d * nh * dh + 2 * d * nkv * dh + nh * dh * d
        dense_mlp = 3 * d * f
        per_block["attn"] = attn + dense_mlp
        if self.n_experts:
            per_block["attn"] = attn + self.n_experts * 3 * d * f + d * self.n_experts
        dr = self.d_rnn_
        per_block["rec"] = (2 * d * dr + dr * self.conv_width + 2 * dr
                            + dr * d) + 3 * d * f
        di = 2 * d  # xlstm inner dim
        per_block["mlstm"] = 2 * d * di + 3 * di * (dh * nh) // max(1, nh) + di * d
        per_block["slstm"] = 4 * d * d + 4 * d * d + d * d
        total = 0.0
        for layer in range(self.n_layers):
            total += per_block.get(self.block_kind(layer), per_block["attn"])
        total += v * d * (1 if self.tie_embeddings else 2)
        return float(total)

    @property
    def n_params_active(self) -> float:
        """Active params per token (MoE counts top_k experts only)."""
        if not self.n_experts:
            return self.n_params
        d, f = self.d_model, self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * d * f * self.n_layers
        return self.n_params - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# declarative parameter specs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Spec:
    """One parameter leaf: shape + logical axes + initializer."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    init: str = "fan_in"             # fan_in | normal | zeros | ones | embed | rglru_a
    scale: float = 1.0
    dtype: Optional[str] = None      # None -> model param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(spec: Spec, key, param_dtype: str) -> jax.Array:
    dt = jnp.dtype(spec.dtype or param_dtype)
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dt)
    if spec.init == "ones":
        return jnp.ones(shape, dt)
    if spec.init == "normal":
        return (jax.random.normal(key, shape) * spec.scale).astype(dt)
    if spec.init == "embed":
        return (jax.random.normal(key, shape) * spec.scale).astype(dt)
    if spec.init == "rglru_a":
        # Lambda init so that a = sigmoid(Lambda) ** c lies in [0.9, 0.999]
        u = jax.random.uniform(key, shape, minval=0.9, maxval=0.999)
        lam = jnp.log(u ** (1.0 / 8.0) / (1 - u ** (1.0 / 8.0)))
        return lam.astype(dt)
    if spec.init == "fan_in":
        # fan-in on the second-to-last dim treated as input (stacked-layer
        # leading dims are ignored for fan computation)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = spec.scale / math.sqrt(max(1, fan_in))
        return (jax.random.normal(key, shape) * std).astype(dt)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(specs, rng, param_dtype: str = "float32"):
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(s, k, param_dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_shapes(specs, param_dtype: str = "float32"):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or param_dtype)),
        specs, is_leaf=lambda x: isinstance(x, Spec))


def param_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, Spec))


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, Spec))
    return int(sum(np.prod(s.shape) for s in leaves))


# ---------------------------------------------------------------------------
# small numerics shared by every model
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token cross-entropy in fp32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) if mask is None else mask
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
