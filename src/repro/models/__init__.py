from .common import (ModelConfig, ShapeConfig, Spec, ALL_SHAPES,
                     SHAPES_BY_NAME, TRAIN_4K, PREFILL_32K, DECODE_32K,
                     LONG_500K, init_params, param_axes, param_shapes,
                     rms_norm, cross_entropy_loss)
from .api import build_model

__all__ = [
    "ModelConfig", "ShapeConfig", "Spec", "ALL_SHAPES", "SHAPES_BY_NAME",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "init_params", "param_axes", "param_shapes", "rms_norm",
    "cross_entropy_loss", "build_model",
]
