"""``python -m repro`` -- the command-line front door.

Subcommands:

* ``monitor``  -- run a monitoring script (``python -m repro monitor
  examples/quickstart.py``) or monitor a named sweep config directly
  (``python -m repro monitor gnmt --mesh 8 --formats html``);
* ``sweep``    -- the config-sweep engine: configs x meshes x algorithms,
  cached, with comparative JSON/CSV/HTML/Perfetto artifacts;
* ``lint``     -- static anti-pattern analysis over a config's (or a saved
  report's) captured collectives, with modeled savings and CI exit codes
  (``--fail-on warn|error``);
* ``compare``  -- import a real device trace (Perfetto JSON / nvprof CSV /
  JSONL) and compare measured vs modeled per-collective seconds, with
  error statistics and CI exit codes (``--fail-on rel-err=X``);
* ``report``   -- re-export a saved report (``CommReport.save`` / cache
  entry) into any format without recompiling anything;
* ``configs``  -- list the sweepable configs;
* ``cache``    -- inspect or clear the on-disk report cache;
* ``bench``    -- the paper-table benchmark suite (``benchmarks/run.py``);
* ``dryrun``   -- the production-scale dry-run launcher
  (``repro.launch.dryrun``).

Argument parsing happens before any jax import so ``--devices`` can still
influence ``XLA_FLAGS`` (host-device count must be set before the backend
initializes).
"""
from __future__ import annotations

import argparse
import os
import sys


def _ensure_devices(n: int):
    from repro.compat import ensure_host_devices
    ensure_host_devices(n)


def _split(csv: str) -> list[str]:
    return [p.strip() for p in csv.split(",") if p.strip()]


# ---------------------------------------------------------------------------
# subcommands
# ---------------------------------------------------------------------------
def _cmd_monitor(args) -> int:
    _ensure_devices(args.devices)
    if args.target.endswith(".py"):
        # run a monitoring script as __main__ (the quickstart path);
        # script exceptions keep their full traceback instead of being
        # mistaken for CLI usage errors by main()'s handler
        import runpy
        import traceback
        if args.formats or args.out != "artifacts":
            print("note: --formats/--out are ignored for script targets -- "
                  "scripts control their own output", file=sys.stderr)
        sys.argv = [args.target] + list(getattr(args, "script_args", []))
        try:
            runpy.run_path(args.target, run_name="__main__")
        except Exception:
            traceback.print_exc()
            return 1
        return 0
    # otherwise: a sweep-config name, monitored on one mesh
    from repro import sweep as sweep_mod

    registry = sweep_mod.available_configs()
    if args.target not in registry:
        print(f"error: {args.target!r} is neither a .py file nor a config; "
              f"known configs: {sorted(registry)}", file=sys.stderr)
        return 2
    result = sweep_mod.run_sweep(
        [args.target], [args.mesh], _split(args.algorithms),
        cache=_cache_from(args), use_cache=not args.no_cache)
    if result.failures:
        print(f"error: {result.failures[0]['error']}", file=sys.stderr)
        return 1
    for rep in result.reports:      # one rendering per requested algorithm
        print(rep.render())
        print()
    if args.formats:
        from repro.core import export
        paths = export.export_comparison(
            result.reports, args.out, _split(args.formats),
            stem=args.target)
        for fmt, path in paths.items():
            print(f"[{fmt}] {path}")
    return 0


def _cmd_sweep(args) -> int:
    _ensure_devices(args.devices)
    from repro import sweep as sweep_mod
    from repro.core import export

    registry = sweep_mod.available_configs()
    unknown = [c for c in _split(args.configs) if c not in registry]
    if unknown:
        print(f"error: unknown config(s) {unknown}; known: "
              f"{sorted(registry)}", file=sys.stderr)
        return 2
    if args.scale_curve:
        return _cmd_scale_curve(args, sweep_mod)
    try:
        jobs = sweep_mod.resolve_jobs(args.jobs)
    except ValueError:
        print(f"error: --jobs wants an int or 'auto', got {args.jobs!r}",
              file=sys.stderr)
        return 2
    result = sweep_mod.run_sweep(
        _split(args.configs), _split(args.meshes), _split(args.algorithms),
        cache=_cache_from(args), use_cache=not args.no_cache, jobs=jobs)
    if not result.reports:
        print("no cell finished; failures:", file=sys.stderr)
        for f in result.failures:
            print(f"  {f}", file=sys.stderr)
        return 1

    table = result.summary_table(by_link=args.by_link,
                                 by_phase=args.by_phase,
                                 lint=args.lint)
    print()
    print(f"== sweep summary: {len(result.reports)} cells "
          f"({result.compiles} compiled, {result.cache_hits} cache hits) ==")
    print(table)
    formats = _split(args.formats)
    result.artifacts = export.export_comparison(
        result.reports, args.out, formats, stem="sweep")
    summary_path = os.path.join(args.out, "summary.txt")
    with open(summary_path, "w") as f:
        f.write(table + "\n")
    result.artifacts["summary"] = summary_path
    print()
    for fmt, path in sorted(result.artifacts.items()):
        print(f"[{fmt}] {path}")
    if result.failures:
        print(f"\n{len(result.failures)} cell(s) failed:", file=sys.stderr)
        for f in result.failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    return 0


def _cmd_scale_curve(args, sweep_mod) -> int:
    """``sweep --scale-curve``: base-mesh monitoring + fleet projection."""
    from repro import scale
    from repro.core.export import csv_exporter, html_exporter

    try:
        device_counts = [int(p) for p in _split(args.scale_points)]
    except ValueError:
        print(f"error: --scale-points wants comma-separated ints, got "
              f"{args.scale_points!r}", file=sys.stderr)
        return 2
    try:
        jobs = sweep_mod.resolve_jobs(args.jobs)
    except ValueError:
        print(f"error: --jobs wants an int or 'auto', got {args.jobs!r}",
              file=sys.stderr)
        return 2
    result, points = sweep_mod.run_scale_curve(
        _split(args.configs), _split(args.meshes), _split(args.algorithms),
        device_counts=device_counts,
        cache=_cache_from(args), use_cache=not args.no_cache, jobs=jobs)
    if not result.reports:
        print("no cell finished; failures:", file=sys.stderr)
        for f in result.failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    table = scale.scale_table(points)
    print()
    print(f"== scale curves: {len(points)} points over "
          f"{len(result.reports)} base cells ==")
    print(table)
    rows = [p.row() for p in points]
    result.artifacts["scale_csv"] = csv_exporter.export_scale_csv(
        rows, os.path.join(args.out, "scale_curve.csv"))
    result.artifacts["scale_html"] = html_exporter.export_scale_html(
        rows, os.path.join(args.out, "scale_curve.html"))
    print()
    for fmt, path in sorted(result.artifacts.items()):
        print(f"[{fmt}] {path}")
    if result.failures:
        print(f"\n{len(result.failures)} cell(s) failed:", file=sys.stderr)
        for f in result.failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    return 0


def _cmd_lint(args) -> int:
    """``repro lint <config-or-report.json>``: print findings, exit 0 when
    clean (or below ``--fail-on``), 1 when findings reach the threshold,
    2 on usage errors (unknown config / algorithm / path)."""
    import json as json_mod
    from repro.core import reporter
    from repro.core.lint import max_severity, severity_rank

    algs = _split(args.algorithms)
    if args.target.endswith(".json"):
        # a saved report / cache entry / sweep document: lint offline --
        # the HLO rules run when the file was saved with include_hlo=True,
        # persisted v7 findings are served as-is for the default binding
        from repro.core import export
        reports = export.load_json_reports(args.target)
        bindings = [(rep, alg) for rep in reports
                    for alg in (algs or [rep.algorithm])]
    else:
        _ensure_devices(args.devices)
        from repro import sweep as sweep_mod
        registry = sweep_mod.available_configs()
        if args.target not in registry:
            print(f"error: unknown config {args.target!r}; known: "
                  f"{sorted(registry)}", file=sys.stderr)
            return 2
        result = sweep_mod.run_sweep(
            [args.target], [args.mesh], algs or ["ring"],
            cache=_cache_from(args), use_cache=not args.no_cache,
            log=lambda m: print(m, file=sys.stderr))
        if result.failures:
            print(f"error: {result.failures[0]['error']}", file=sys.stderr)
            return 1
        bindings = [(rep, rep.algorithm) for rep in result.reports]

    all_findings = []
    docs = []
    for rep, alg in bindings:
        findings = rep.lint(alg)
        all_findings += findings
        if args.as_json:
            docs.append({"name": rep.name, "algorithm": alg,
                         "max_severity": max_severity(findings),
                         "findings": [f.to_dict() for f in findings]})
        else:
            print(reporter.lint_table(
                findings, title=f"{rep.name} [{alg}]: lint findings"))
            print()
    if args.as_json:
        print(json_mod.dumps(docs[0] if len(docs) == 1 else docs, indent=1))
    if args.fail_on is not None:
        threshold = severity_rank(args.fail_on)
        if any(severity_rank(f.severity) >= threshold
               for f in all_findings):
            return 1
    return 0


def _is_saved_report(path: str) -> bool:
    """Whether ``path`` is a CommReport.save JSON (vs a device trace)."""
    if not path.endswith(".json") or not os.path.exists(path):
        return False
    try:
        with open(path, errors="replace") as f:
            return '"repro.comm_report' in f.read(2048)
    except OSError:
        return False


def _cmd_compare(args) -> int:
    """``repro compare <trace> [model]``: import a real device trace and
    pin its measured per-collective seconds against the cost model.
    Exit 0 on a finished comparison (below ``--fail-on``), 1 when the
    ``--fail-on rel-err=X`` threshold is hit, 2 on usage errors (bad
    path / format / config / threshold)."""
    import json as json_mod

    from repro.core.trace import FORMATS, load_trace

    def log(msg):
        print(msg, file=sys.stderr)

    threshold = None
    if args.fail_on:
        key, _, val = args.fail_on.partition("=")
        try:
            if key.strip() != "rel-err":
                raise ValueError
            threshold = float(val)
        except ValueError:
            print(f"error: --fail-on wants rel-err=<float> (e.g. "
                  f"rel-err=0.25), got {args.fail_on!r}", file=sys.stderr)
            return 2
    if args.fmt and args.fmt not in FORMATS:
        print(f"error: unknown trace format {args.fmt!r}; valid formats: "
              f"{list(FORMATS)}", file=sys.stderr)
        return 2

    if _is_saved_report(args.trace):
        # a saved v9 report of an earlier import (--save-import): its ops
        # already carry measured_s, no trace frontend needed
        from repro.core import CommReport
        measured = CommReport.load(args.trace)
        log(f"loaded saved report {args.trace}: "
            f"{len(measured.compiled_ops)} collectives, "
            f"{measured.num_devices} devices")
    else:
        imp = load_trace(args.trace, fmt=args.fmt or None,
                         num_devices=args.trace_devices)
        measured = imp.report()
        log(f"imported {args.trace} [{imp.meta.get('source')}]: "
            f"{len(measured.compiled_ops)} collectives, "
            f"{len(measured.host_transfers)} host transfers, "
            f"{measured.num_devices} devices")
    if args.save_import:
        measured.save(args.save_import)
        log(f"[report] {args.save_import}")

    algs = _split(args.algorithms)
    models: list = []
    if not args.model:
        # the import's own model (needs a topology, e.g. our own exports)
        models = [(None, a or None) for a in (algs or [None])]
    elif args.model.endswith(".json"):
        from repro.core import export
        reports = export.load_json_reports(args.model)
        models = [(rep, alg) for rep in reports
                  for alg in (algs or [rep.algorithm])]
    else:
        _ensure_devices(args.devices)
        from repro import sweep as sweep_mod
        registry = sweep_mod.available_configs()
        if args.model not in registry:
            print(f"error: unknown config {args.model!r}; known configs: "
                  f"{sorted(registry)}", file=sys.stderr)
            return 2
        result = sweep_mod.run_sweep(
            [args.model], [args.mesh], algs or ["ring"],
            cache=_cache_from(args), use_cache=not args.no_cache, log=log)
        if result.failures:
            print(f"error: {result.failures[0]['error']}", file=sys.stderr)
            return 1
        models = [(rep, rep.algorithm) for rep in result.reports]

    results = []
    for model_rep, alg in models:
        cr = measured.compare(model_rep, algorithm=alg)
        results.append(cr)
        if not args.as_json:
            print(cr.table(
                title=f"== {cr.measured_label} vs {cr.modeled_label} "
                      f"[{cr.algorithm}]: modeled vs measured =="))
            print()
    if args.as_json:
        docs = [cr.to_dict() for cr in results]
        print(json_mod.dumps(docs[0] if len(docs) == 1 else docs,
                             indent=1))
    for fmt in _split(args.formats):
        from repro.core.export import csv_exporter, html_exporter
        stem = os.path.splitext(os.path.basename(args.trace))[0]
        if fmt == "csv":
            path = csv_exporter.export_compare_csv(
                results[0], os.path.join(args.out, f"{stem}_compare.csv"))
        elif fmt == "html":
            path = html_exporter.export_compare_html(
                results, os.path.join(args.out, f"{stem}_compare.html"))
        else:
            print(f"error: unknown compare export format {fmt!r}; valid "
                  f"formats: ['csv', 'html']", file=sys.stderr)
            return 2
        log(f"[{fmt}] {path}")
    if threshold is not None:
        worst = max((cr.max_rel_err() or 0.0) for cr in results)
        if worst > threshold:
            log(f"fail: max rel err {worst:.3f} exceeds --fail-on "
                f"threshold {threshold:.3f}")
            return 1
    return 0


def _cmd_report(args) -> int:
    from repro.core import export

    reports = export.load_json_reports(args.path)   # report, cache entry,
    if args.render:                                 # or sweep document
        for rep in reports:
            print(rep.render())
            print()
    stem = os.path.splitext(os.path.basename(args.path))[0]
    if stem.endswith(".trace"):
        stem = stem[:-len(".trace")]
    if len(reports) == 1:
        for fmt in _split(args.formats):
            path = os.path.join(args.out, stem + export.SUFFIXES.get(fmt, ""))
            export.export_report(reports[0], fmt, path)   # validates fmt
            print(f"[{fmt}] {path}")
    else:
        for fmt, path in export.export_comparison(
                reports, args.out, _split(args.formats), stem=stem).items():
            print(f"[{fmt}] {path}")
    return 0


def _cmd_configs(args) -> int:
    from repro import sweep as sweep_mod
    from repro.core.reporter import format_table

    registry = sweep_mod.available_configs()
    rows = [[s.name, s.version, s.description]
            for s in registry.values()]
    print(format_table(rows, ["config", "version", "description"]))
    return 0


def _cmd_cache(args) -> int:
    cache = _cache_from(args)
    if args.clear:
        n = cache.clear()
        print(f"cleared {n} entries from {cache.root}")
        return 0
    entries = cache.entries()
    total = sum(e["size"] for e in entries)
    print(f"cache {cache.root}: {len(entries)} entries, {total:,} bytes")
    for e in entries:
        meta = e.get("meta", {})
        tag = (f"{meta.get('config', '?')} mesh={meta.get('mesh', '?')} "
               f"alg={meta.get('algorithm', '?')}")
        print(f"  {e['key']}  {e['size']:>9,} B  {tag}")
    return 0


def _cmd_bench(args) -> int:
    _ensure_devices(args.devices)
    sys.path.insert(0, os.getcwd())   # benchmarks/ is a repo-root package
    try:
        from benchmarks import run as bench_run
    except ImportError:
        print("error: benchmarks package not importable -- run from the "
              "repo root", file=sys.stderr)
        return 2
    return bench_run.main(args.names)


def _cmd_dryrun(args) -> int:
    from repro.launch import dryrun
    return dryrun.main(args.rest)


def _cache_from(args):
    from repro.core.report_cache import ReportCache
    return ReportCache(root=getattr(args, "cache_dir", None) or None)


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------
def _add_cache_opts(p):
    p.add_argument("--cache-dir", default=None,
                   help="report-cache directory (default "
                        "artifacts/report_cache or $REPRO_CACHE_DIR)")
    p.add_argument("--no-cache", action="store_true",
                   help="neither read nor write the report cache")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Monitor collective communication among accelerators "
                    "(ComScribe, TPU/JAX edition).")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("monitor",
                       help="run a monitoring script or one sweep config")
    p.add_argument("target", help="a .py script or a sweep-config name")
    p.add_argument("--mesh", default="4x2", help="mesh spec, e.g. 8 or 4x2")
    p.add_argument("--algorithms", default="ring")
    p.add_argument("--formats", default="",
                   help="also export: comma list of json,csv,html,perfetto")
    p.add_argument("--out", default="artifacts")
    p.add_argument("--devices", type=int, default=8)
    _add_cache_opts(p)
    p.set_defaults(func=_cmd_monitor)

    p = sub.add_parser("sweep", help="sweep configs x meshes x algorithms")
    p.add_argument("--configs", required=True,
                   help="comma list (see `python -m repro configs`)")
    p.add_argument("--meshes", default="4x2",
                   help="comma list of mesh specs, e.g. 8,4x2,2x2x2")
    p.add_argument("--algorithms", default="ring",
                   help="comma list of ring,tree,hierarchical")
    p.add_argument("--by-link", action="store_true", dest="by_link",
                   help="add per-link utilization columns (busiest physical "
                        "ICI/DCN link, the tier-overlapped communication "
                        "time, and its contention-aware bottleneck "
                        "ms) to the summary table")
    p.add_argument("--by-phase", action="store_true", dest="by_phase",
                   help="expand each cell into one row per session phase "
                        "(statistics from that phase's CommView)")
    p.add_argument("--lint", action="store_true",
                   help="add static-lint columns (finding count at worst "
                        "severity + total modeled savings ms) per cell")
    p.add_argument("--scale-curve", action="store_true", dest="scale_curve",
                   help="monitor each cell at its base mesh, then project "
                        "onto synthetic fleet topologies per --scale-points "
                        "device count (sparse matrices throughout; emits "
                        "scale_curve.csv + scale_curve.html)")
    p.add_argument("--scale-points", default="256,1024,4096,16384",
                   dest="scale_points",
                   help="comma list of fleet device counts for --scale-curve")
    p.add_argument("--jobs", "-j", default="1",
                   help="evaluate (config, mesh) cells on N worker threads "
                        "('-j auto' = one per CPU).  Output is identical "
                        "to the default serial run (-j 1, the CI setting): "
                        "results are assembled in deterministic order")
    p.add_argument("--formats", default="json,csv,html,perfetto")
    p.add_argument("--out", default=os.path.join("artifacts", "sweep"))
    p.add_argument("--devices", type=int, default=8)
    _add_cache_opts(p)
    p.set_defaults(func=_cmd_sweep)

    p = sub.add_parser("lint", help="static anti-pattern analysis with "
                                    "modeled savings (CI exit codes)")
    p.add_argument("target",
                   help="a sweep-config name or a saved report .json "
                        "(CommReport.save / cache entry / sweep document)")
    p.add_argument("--mesh", default="4x2",
                   help="mesh spec for config targets, e.g. 8, 4x2, 2x2x2")
    p.add_argument("--algorithms", default="",
                   help="comma list of ring,tree,hierarchical; default: "
                        "the report's own binding (ring for configs)")
    p.add_argument("--fail-on", choices=["warn", "error"], default=None,
                   dest="fail_on",
                   help="exit 1 when any finding is at or above this "
                        "severity (default: always exit 0 when the "
                        "analysis ran)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable findings JSON on stdout")
    p.add_argument("--devices", type=int, default=8)
    _add_cache_opts(p)
    p.set_defaults(func=_cmd_lint)

    p = sub.add_parser("compare",
                       help="import a device trace and compare measured "
                            "vs modeled per-collective seconds")
    p.add_argument("trace",
                   help="a trace file: Perfetto/Chrome JSON (jax profiler "
                        "or our own export), nvprof/ComScribe CSV, or the "
                        "generic JSONL schema")
    p.add_argument("model", nargs="?", default="",
                   help="the modeled side: a sweep-config name or a saved "
                        "report .json (default: the imported trace's own "
                        "model, which needs a topology -- true for our "
                        "own Perfetto exports)")
    p.add_argument("--fmt", default="",
                   help="force a trace frontend: perfetto, nvprof, jsonl "
                        "(default: sniff the file)")
    p.add_argument("--trace-devices", type=int, default=None,
                   dest="trace_devices",
                   help="device count of the traced job (default: from "
                        "the trace; device ids are validated against it)")
    p.add_argument("--mesh", default="4x2",
                   help="mesh spec for config models, e.g. 8, 4x2, 2x2x2")
    p.add_argument("--algorithms", default="",
                   help="comma list of ring,tree,hierarchical; default: "
                        "the model's own binding")
    p.add_argument("--fail-on", default=None, dest="fail_on",
                   metavar="rel-err=X",
                   help="exit 1 when the max relative error exceeds X "
                        "(e.g. rel-err=0.25)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable comparison JSON on stdout "
                        "(sweep logs go to stderr)")
    p.add_argument("--formats", default="",
                   help="also export: comma list of csv,html")
    p.add_argument("--out", default="artifacts")
    p.add_argument("--save-import", default="", dest="save_import",
                   help="also save the imported trace as a schema-v9 "
                        "report JSON at this path")
    p.add_argument("--devices", type=int, default=8)
    _add_cache_opts(p)
    p.set_defaults(func=_cmd_compare)

    p = sub.add_parser("report", help="re-export a saved report")
    p.add_argument("path", help="a CommReport.save JSON file")
    p.add_argument("--formats", default="html")
    p.add_argument("--out", default="artifacts")
    p.add_argument("--render", action="store_true",
                   help="also print the terminal rendering")
    p.set_defaults(func=_cmd_report)

    p = sub.add_parser("configs", help="list sweepable configs")
    p.set_defaults(func=_cmd_configs)

    p = sub.add_parser("cache", help="inspect / clear the report cache")
    p.add_argument("--clear", action="store_true")
    _add_cache_opts(p)
    p.set_defaults(func=_cmd_cache)

    p = sub.add_parser("bench", help="paper-table benchmark suite")
    p.add_argument("names", nargs="*",
                   help="table1 table2 table3 fig3 links matrix overhead "
                        "roofline (default: all)")
    p.add_argument("--devices", type=int, default=8)
    p.set_defaults(func=_cmd_bench)

    p = sub.add_parser("dryrun", add_help=False,
                       help="production-scale dry-run launcher "
                            "(all arguments forwarded to repro.launch.dryrun)")
    p.set_defaults(func=_cmd_dryrun, rest=[])
    return ap


def main(argv=None) -> int:
    parser = build_parser()
    # dryrun forwards everything (including --flags, which REMAINDER cannot
    # capture) to repro.launch.dryrun's own parser
    args, extra = parser.parse_known_args(argv)
    if args.func is _cmd_dryrun:
        args.rest = extra
    elif (args.func is _cmd_monitor and args.target.endswith(".py")):
        args.script_args = extra     # forwarded to the script's own argv
    elif extra:
        parser.error(f"unrecognized arguments: {' '.join(extra)}")
    try:
        return args.func(args) or 0
    except (ValueError, FileNotFoundError) as e:
        # spec / format / path errors are user errors, not crashes
        # (anything else -- including KeyError -- keeps its traceback)
        msg = e.args[0] if isinstance(e, ValueError) and e.args else e
        print(f"error: {msg}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
