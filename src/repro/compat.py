"""Version tolerance for the handful of jax APIs that moved across releases.

The repo targets current jax, but must degrade gracefully on the oldest
toolchain we support (0.4.x): ``jax.sharding.AxisType`` and ``jax.shard_map``
only exist on newer versions, so every call site goes through these wrappers
instead of feature-detecting locally.
"""
from __future__ import annotations

import os

import jax


def ensure_host_devices(n: int):
    """Append ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``.

    Appends rather than overwrites so user-set flags survive; an existing
    device-count flag (user-chosen) wins.  Must run before the jax backend
    initializes (first device query).
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def mesh_axis_types_kw(n_axes: int) -> dict:
    """``{"axis_types": (Auto,)*n}`` where the jax API supports it, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         **mesh_axis_types_kw(len(axis_names)))


_HAS_COMBINER: bool | None = None


def has_allreduce_combiner() -> bool:
    """Does this jaxlib's compiler combine independent all-reduces?

    XLA's all-reduce combiner pass performs DDP-style gradient bucketing
    automatically (paper Table 3's optimization, done by the compiler).
    Old CPU jaxlibs (0.4.x) never run it, so per-parameter psums stay
    1-per-tensor in the compiled module.  This probes the actual behavior
    -- compile a two-psum program and count the surviving all-reduce ops --
    rather than guessing from version strings.  The result is cached for
    the process (one small compile, first call only).
    """
    global _HAS_COMBINER
    if _HAS_COMBINER is not None:
        return _HAS_COMBINER

    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((jax.device_count(),), ("_probe",))

    def two_psums(a, b):
        return (jax.lax.psum(a, "_probe"), jax.lax.psum(b, "_probe"))

    fn = jax.jit(shard_map(two_psums, mesh=mesh,
                           in_specs=(P("_probe"), P("_probe")),
                           out_specs=(P("_probe"), P("_probe"))))
    import jax.numpy as jnp
    args = [jax.ShapeDtypeStruct((jax.device_count(), 8), jnp.float32)] * 2
    hlo = fn.lower(*args).compile().as_text()
    n_ar = len([l for l in hlo.splitlines()
                if " all-reduce(" in l or " all-reduce-start(" in l])
    _HAS_COMBINER = n_ar <= 1
    return _HAS_COMBINER


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map``, falling back to the pre-promotion experimental API
    (where ``check_vma`` was spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
