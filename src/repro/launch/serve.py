"""Serving driver: batched prefill + decode with a monitored comm profile.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_8b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import monitor_fn
from repro.launch.mesh import make_test_mesh
from repro.models import build_model
from repro.parallel import Sharder
from repro.serve import ServeConfig, generate, make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--mesh", default="2x2")
    ap.add_argument("--report", default="")
    args = ap.parse_args(argv)

    shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(shape, ("data", "model")[:len(shape)])
    shd = Sharder(mesh)
    cfg = configs.config(args.arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.device_put(params,
                            shd.tree_shardings(model.shapes(), model.axes()))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, shd, steps=args.tokens,
                   max_len=args.prompt_len + args.tokens)
    dt = time.perf_counter() - t0
    tps = args.batch * args.tokens / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("[serve] sample:", out[0, :16].tolist())

    if args.report:
        scfg = ServeConfig(max_len=args.prompt_len + args.tokens,
                           batch=args.batch)
        params_sh = shd.tree_shardings(model.shapes(), model.axes())
        cache_shapes = model.cache_shapes(args.batch, scfg.max_len)
        rep = monitor_fn(
            lambda p, c, b: model.decode_step(p, c, b, shd),
            model.shapes(), cache_shapes,
            {"tokens": jax.ShapeDtypeStruct((args.batch, 1), jnp.int32)},
            mesh=mesh, name=f"decode[{args.arch}]")
        print(rep.render())
        rep.save(args.report)
    return out


if __name__ == "__main__":
    main()
