"""End-to-end training driver.

Runs a REAL training loop (reduced arch configs on CPU; the same code path
scales to the production meshes) with: deterministic data, checkpoint/resume
(fault tolerance), async checkpointing, and a communication report from the
monitor at the end — the paper's workflow folded into the trainer.

  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --resume ...
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core import monitor_fn
from repro.data import SyntheticLMData, host_transfer_log
from repro.launch.mesh import make_test_mesh
from repro.models import build_model
from repro.optim import OptConfig
from repro.parallel import Sharder
from repro.train import TrainConfig, init_train_state
from repro.train.train import (batch_shardings, make_train_step,
                               train_state_shardings)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="2x2")
    ap.add_argument("--report", default="", help="write CommReport JSON here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(shape, ("data", "model")[:len(shape)])
    shd = Sharder(mesh)

    cfg = configs.config(args.arch, reduced=True)
    model = build_model(cfg)
    ocfg = OptConfig(peak_lr=args.lr, warmup_steps=10,
                     decay_steps=max(100, args.steps))
    tcfg = TrainConfig(microbatches=args.microbatches)

    state = init_train_state(model, ocfg, jax.random.PRNGKey(args.seed))
    state_sh = train_state_shardings(model, ocfg, shd)
    state = jax.device_put(state, state_sh)

    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                           global_batch=args.global_batch, seed=args.seed)
    batch0 = data.batch_at(0)
    b_sh = batch_shardings(jax.eval_shape(lambda: batch0), shd)

    step_fn = jax.jit(make_train_step(model, ocfg, tcfg, shd),
                      in_shardings=(state_sh, b_sh),
                      out_shardings=(state_sh, None))

    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            last = latest_step(args.ckpt_dir)
            if last is not None:
                state = restore_checkpoint(args.ckpt_dir, last, state,
                                           shardings=state_sh)
                start = last
                print(f"[train] resumed from step {last}")

    t0 = time.perf_counter()
    losses = []
    for step in range(start, args.steps):
        batch = jax.device_put(data.batch_at(step), b_sh)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.1f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.wait()

    if args.report:
        rep = monitor_fn(make_train_step(model, ocfg, tcfg, shd),
                         jax.tree.map(lambda x: jax.ShapeDtypeStruct(
                             x.shape, x.dtype), state),
                         jax.eval_shape(lambda: batch0),
                         mesh=mesh, name=f"train[{args.arch}]",
                         in_shardings=(state_sh, b_sh),
                         host_transfers=host_transfer_log())
        print(rep.render())
        rep.save(args.report)
    if losses:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    else:
        print(f"[train] nothing to do (resumed at step {start})")
    return losses


if __name__ == "__main__":
    main()
