"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).

Single pod : (data=16, model=16)            = 256 chips (one v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips; "pod" crosses DCN
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    import numpy as np
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — the dry-run launcher must "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    # slice explicitly: a 512-device process also builds the 256-chip mesh
    from jax.sharding import Mesh
    from repro.compat import mesh_axis_types_kw
    return Mesh(np.array(devs[:n]).reshape(shape), axes,
                **mesh_axis_types_kw(len(axes)))


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for tests/examples on forced host devices."""
    from repro.compat import make_mesh
    return make_mesh(shape, axes)


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape) + ":" + \
        ",".join(map(str, mesh.axis_names))
