"""Multi-pod dry-run launcher.

For every (architecture x input-shape x mesh) cell this lowers + compiles the
real step function (train_step / prefill / decode) against ShapeDtypeStruct
stand-ins — no device memory is allocated — and records:

* ``compiled.memory_analysis()``  (bytes per device: proves it fits),
* ``compiled.cost_analysis()``    (FLOPs / bytes for the roofline),
* the collective schedule parsed from the compiled HLO (the paper's
  contribution makes this visible), and
* the three-term roofline row (EXPERIMENTS.md §Roofline).

Usage (the CLI forwards `python -m repro dryrun ...` here):
  python -m repro dryrun --arch grok_1_314b --shape train_4k --mesh single
  python -m repro dryrun --all --mesh both --skip-existing

The 512-host-device XLA flag is applied inside :func:`main` (not at import
time) so importing this module for its cell builders -- as the sweep engine
does -- never clobbers the caller's device configuration.
"""
import argparse
import gzip
import json
import os
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import hlo_parser, roofline
from repro.core.topology import MeshTopology
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.models import SHAPES_BY_NAME, build_model
from repro.models.common import ShapeConfig
from repro.optim import OptConfig
from repro.parallel import Sharder
from repro.serve import ServeConfig, make_decode_step, make_prefill_step
from repro.train.train import (batch_shardings, jit_train_step,
                               train_state_shapes, train_state_shardings)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _memory_stats(compiled):
    m = compiled.memory_analysis()
    return {
        "argument_bytes": int(m.argument_size_in_bytes),
        "output_bytes": int(m.output_size_in_bytes),
        "temp_bytes": int(m.temp_size_in_bytes),
        "alias_bytes": int(m.alias_size_in_bytes),
        "total_bytes": int(m.argument_size_in_bytes + m.output_size_in_bytes
                           + m.temp_size_in_bytes - m.alias_size_in_bytes),
    }


def _cost(compiled):
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return {k: float(v) for k, v in dict(c).items()
            if isinstance(v, (int, float))}


def lower_cell(arch: str, shape_name: str, mesh, *, opt_name=None,
               sp: bool = False, train_overrides=None):
    """Build and lower one cell.  Returns (lowered, aux dict)."""
    cfg = configs.config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    model = build_model(cfg)
    shd = Sharder(mesh, enable_sp=sp)
    batch = configs.input_specs(cfg, shape)

    if shape.kind == "train":
        tcfg = configs.train_config(arch)
        if train_overrides:
            import dataclasses
            tcfg = dataclasses.replace(tcfg, **train_overrides)
        ocfg = OptConfig(name=opt_name or cfg.optimizer,
                         state_dtype=cfg.opt_state_dtype)
        from repro.train.train import make_train_step, train_state_shardings
        step_fn = make_train_step(model, ocfg, tcfg, shd)
        state_sh = train_state_shardings(model, ocfg, shd)
        state_shapes = train_state_shapes(model, ocfg)
        b_sh = batch_shardings(batch, shd)
        step = jax.jit(step_fn,
                       in_shardings=(state_sh, b_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,))
        lowered = step.lower(state_shapes, batch)
        n_tokens = shape.global_batch * shape.seq_len
        model_flops = roofline.train_model_flops(cfg.n_params_active, n_tokens)
    elif shape.kind == "prefill":
        scfg = ServeConfig(max_len=shape.seq_len, batch=shape.global_batch)
        params_sh = shd.tree_shardings(model.shapes(), model.axes())
        step, _ = make_prefill_step(model, shd, scfg, params_sh=params_sh)
        b_sh = batch_shardings(batch, shd)
        lowered = step.lower(model.shapes(), batch)
        model_flops = roofline.forward_model_flops(
            cfg.n_params_active, shape.global_batch * shape.seq_len)
    else:  # decode
        scfg = ServeConfig(max_len=shape.seq_len, batch=shape.global_batch)
        params_sh = shd.tree_shardings(model.shapes(), model.axes())
        step, cache_sh = make_decode_step(model, shd, scfg,
                                          params_sh=params_sh,
                                          donate_cache=True)
        cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
        lowered = step.lower(model.shapes(), cache_shapes, batch)
        model_flops = roofline.forward_model_flops(
            cfg.n_params_active, shape.global_batch)
    return lowered, {"cfg": cfg, "shape": shape, "model_flops": model_flops}


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, save_hlo=False,
             out_dir=ARTIFACT_DIR, sp: bool = False, tag: str = "",
             train_overrides=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = "multi" if multi_pod else "single"
    t0 = time.perf_counter()
    lowered, aux = lower_cell(arch, shape_name, mesh, sp=sp,
                              train_overrides=train_overrides)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    hlo = compiled.as_text()
    topo = MeshTopology.from_mesh(mesh)
    cost = _cost(compiled)
    rl = roofline.analyze(
        arch=arch, mesh_name=mname, cost=cost, hlo_text=hlo, topo=topo,
        model_flops=aux["model_flops"], memory_stats=_memory_stats(compiled))

    result = {
        "arch": arch, "shape": shape_name, "mesh": mname,
        "devices": topo.num_devices,
        "ok": True,
        "trace_s": t1 - t0, "compile_s": t2 - t1,
        "memory": _memory_stats(compiled),
        "cost": {k: cost.get(k, 0.0) for k in ("flops", "bytes accessed")},
        "collectives": rl.collective_breakdown,
        "roofline": roofline.to_row(rl),
        "tag": tag,
    }
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch}_{shape_name}_{mname}" + (f"_{tag}" if tag else "")
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    if save_hlo:
        with gzip.open(os.path.join(out_dir, stem + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    return result


def main(argv=None) -> int:
    from repro.compat import ensure_host_devices
    ensure_host_devices(512)
    ap = argparse.ArgumentParser(prog="python -m repro dryrun")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--sp", action="store_true", help="sequence parallelism")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args(argv)

    todo = configs.cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in todo:
        for mp in meshes:
            mname = "multi" if mp else "single"
            stem = f"{arch}_{shape}_{mname}" + \
                (f"_{args.tag}" if args.tag else "")
            path = os.path.join(args.out, stem + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {stem}")
                continue
            print(f"[dryrun] {arch} x {shape} @ {mname} ...", flush=True)
            try:
                r = run_cell(arch, shape, mp, save_hlo=args.save_hlo,
                             out_dir=args.out, sp=args.sp, tag=args.tag)
                mem = r["memory"]["total_bytes"] / 2**30
                rl = r["roofline"]
                print(f"  ok: mem/dev={mem:.2f} GiB "
                      f"compute={rl['compute_s']:.3e}s "
                      f"memory={rl['memory_s']:.3e}s "
                      f"collective={rl['collective_s']:.3e}s "
                      f"dominant={rl['dominant']} "
                      f"(trace {r['trace_s']:.1f}s compile {r['compile_s']:.1f}s)",
                      flush=True)
            except Exception as e:
                failures.append((arch, shape, mname, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nall dry-run cells passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
