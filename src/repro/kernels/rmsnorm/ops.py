"""Dispatch wrapper for RMSNorm."""
from __future__ import annotations

import jax


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def rmsnorm(x, w, eps: float = 1e-6, *, force: str = ""):
    backend = force or ("pallas" if _on_tpu() else "xla")
    if backend in ("pallas", "pallas_interpret"):
        from .kernel import rmsnorm_pallas
        return rmsnorm_pallas(x, w, eps=eps,
                              interpret=(backend == "pallas_interpret"))
    from .ref import rmsnorm_ref
    return rmsnorm_ref(x, w, eps)
