"""RMSNorm as a Pallas TPU kernel: one fused VMEM pass.

Bandwidth-bound exemplar: XLA emits (square -> reduce -> rsqrt -> mul -> mul)
which fuses already, but materializes fp32 intermediates for bf16 inputs;
the kernel reads each row once, reduces in VREGs, writes once.

Grid: ``(rows // block_rows,)`` over the flattened (B*S) row dim.
BlockSpec: (block_rows, D) VMEM tile (D = model width, fp32 accumulate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    # cast-then-scale matches models.common.rms_norm bit-for-bit
    o_ref[...] = y.astype(o_ref.dtype) * w_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x, w, *, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False):
    """x: (..., D); w: (D,) -> same shape as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
