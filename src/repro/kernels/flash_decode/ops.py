"""Dispatch wrapper for decode attention: Pallas flash-decode on TPU,
grouped-einsum XLA path elsewhere (what the CPU dry-run lowers)."""
from __future__ import annotations

import jax


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def decode_attend(q, k_cache, v_cache, cache_len, *, window: int = 0,
                  force: str = ""):
    """q: (B,H,dh); k/v: (B,L,KVH,dh) -> (B,H,dh)."""
    backend = force or ("pallas" if _on_tpu() else "xla")
    if backend in ("pallas", "pallas_interpret"):
        from .kernel import flash_decode
        lmax = k_cache.shape[1]
        bk = 512 if lmax % 512 == 0 else (128 if lmax % 128 == 0 else lmax)
        return flash_decode(q, k_cache, v_cache, cache_len, window=window,
                            block_k=bk,
                            interpret=(backend == "pallas_interpret"))
    from .ref import decode_ref
    return decode_ref(q, k_cache, v_cache, cache_len, window=window)
