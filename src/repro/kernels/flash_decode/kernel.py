"""Flash-decode: single-token attention over a deep KV cache, as Pallas.

Decode is the memory-bound regime (roofline table: every ``decode_32k`` cell)
— the step reads the whole cache once and does ~2 FLOPs/byte.  The win over
the XLA path is eliminating the fp32 materializations around the score
vector: the cache streams through VMEM in (block_k x dh) tiles, the online
softmax lives in VREG-resident scratch, and HBM traffic is exactly
``k + v + q + out`` bytes.

Grid: ``(B, H, L // block_k)`` — kv-block innermost (sequential on TPU), so
scratch (acc, m, l) carries the online softmax and is finalized on the last
block.  GQA maps query head h to cache head ``h // G`` in the BlockSpec
index_map.  ``cache_len`` arrives as a scalar-prefetch operand; blocks
entirely past it are skipped (``pl.when``), so a short cache in a long
buffer costs only the occupied blocks' bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, block_k: int, nk: int, scale: float,
                   window: int):
    ki = pl.program_id(2)
    cache_len = len_ref[0]
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = k_start < cache_len
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k > cache_len - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (dh,)
        k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, dh)
        s = jnp.sum(q[None, :] * k, axis=-1)                 # (Bk,)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)
        mask = kpos < cache_len
        if window > 0:
            mask &= kpos >= cache_len - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)         # (Bk,)
        v = v_ref[0, 0].astype(jnp.float32)                  # (Bk, dh)
        acc_ref[...] = acc_ref[...] * alpha + jnp.sum(
            p[:, None] * v, axis=0)
        l_ref[0] = l_ref[0] * alpha + p.sum()
        m_ref[0] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0, ...] = (acc_ref[...] /
                            jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, cache_len, *, window: int = 0,
                 block_k: int = 512, interpret: bool = False):
    """q: (B,H,dh); k/v: (B,L,KVH,dh); cache_len: () int32 -> (B,H,dh)."""
    b, h, dh = q.shape
    _, lmax, kvh, _ = k_cache.shape
    g = h // kvh
    block_k = min(block_k, lmax)
    assert lmax % block_k == 0, (lmax, block_k)
    nk = lmax // block_k

    kt = k_cache.swapaxes(1, 2)                              # (B,KVH,L,dh)
    vt = v_cache.swapaxes(1, 2)
    qt = q[:, :, None, :]                                    # (B,H,1,dh)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, block_k=block_k, nk=nk,
                               scale=dh ** -0.5, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, ki: (0,)),
            pl.BlockSpec((1, 1, dh), lambda b_, h_, ki: (b_, h_, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h_, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h_, ki: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda b_, h_, ki: (b_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            _scratch((dh,)),     # acc
            _scratch((1,)),      # m
            _scratch((1,)),      # l
        ],
        interpret=interpret,
    )(cache_len, qt.reshape(b, h, dh), kt, vt)
    return out


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    try:
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover
        return None
