"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_ref(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """q: (B,H,dh); k/v: (B,L,KVH,dh); cache_len: () int32 -> (B,H,dh)."""
    b, h, dh = q.shape
    _, lmax, kvh, _ = k_cache.shape
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(lmax)
    mask = kpos < cache_len
    if window > 0:
        mask &= kpos >= cache_len - window
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)
