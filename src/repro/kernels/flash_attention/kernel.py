"""Flash attention as a Pallas TPU kernel.

Blockwise online-softmax attention (Dao et al., adapted to the TPU memory
hierarchy): the (Sq, Skv) score matrix never leaves VMEM; HBM traffic is
O(S * dh) instead of O(S^2).

Grid: ``(B, H, nq, nk)`` — the trailing (kv) dimension is innermost and
sequential on TPU, so VMEM scratch accumulators (acc, m, l) carry the online
softmax across kv blocks of one (b, h, q-block) cell and are finalized on the
last kv step.

BlockSpecs (all VMEM):
  q:   (1, 1, Bq, dh)   indexed (b, h, qi)       — revisited across ki
  k,v: (1, 1, Bk, dh)   indexed (b, h // G, ki)  — GQA: query-head groups
                                                    share a kv head
  out: (1, 1, Bq, dh)   indexed (b, h, qi)

Causal/window masking is positional; blocks fully outside the mask are
skipped with ``pl.when`` (the MXU never sees them).  Block sizes default to
(128, 512): q/k/v tiles are MXU-aligned (128 lanes) and the working set
(q + k + v + acc + p) stays under ~4 MiB of VMEM for dh <= 256.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, nk: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_start = q_offset + qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # --- block-level mask culling -------------------------------------
    # causal: skip blocks strictly above the diagonal
    # window: skip blocks entirely older than (q_start - window)
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (Bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                   # (Bq,)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # fully masked rows: keep contributions at exactly zero
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)                  # (Bk, dh)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0, ...] = (
            acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k",
                     "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 512,
                    q_offset: int = 0, interpret: bool = False):
    """q: (B,Sq,H,dh); k,v: (B,Skv,KVH,dh) -> (B,Sq,H,dh)."""
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    nq, nk = sq // block_q, skv // block_k
    scale = dh ** -0.5

    # layout: (B, H, S, dh) blocks
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pl_scratch((block_q, dh)),   # acc
            pl_scratch((block_q,)),      # m (running max)
            pl_scratch((block_q,)),      # l (running denom)
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.swapaxes(1, 2)


def pl_scratch(shape):
    """fp32 VMEM scratch accumulator."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # CPU interpret fallback
        return pl.MemorySpace.ANY(shape, jnp.float32)  # pragma: no cover


def vmem_bytes(block_q: int, block_k: int, dh: int, dtype_bytes: int = 2) -> int:
    """Working-set estimate for one grid cell (used to pick block sizes)."""
    q = block_q * dh * dtype_bytes
    kv = 2 * block_k * dh * dtype_bytes
    s_p = 2 * block_q * block_k * 4
    acc = block_q * dh * 4 + 2 * block_q * 4
    return q + kv + s_p + acc
