"""Dispatch wrapper for attention: Pallas kernel on TPU, chunked-XLA oracle
elsewhere (CPU dry-runs / smoke tests).

``attend`` is the call-site used by every transformer model in the framework;
the choice of backend never changes numerics beyond dtype-accumulation noise
(asserted in tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def attend(q, k, v, *, causal: bool = True, window: int = 0,
           q_chunk: int = 512, q_offset: int = 0, force: str = ""):
    """q: (B,Sq,H,dh); k,v: (B,Skv,KVH,dh) -> (B,Sq,H,dh).

    ``force``: "" (auto) | "pallas" | "pallas_interpret" | "xla" | "ref".
    """
    backend = force or ("pallas" if _on_tpu() else "xla")
    if backend in ("pallas", "pallas_interpret"):
        from .kernel import flash_attention
        sq, skv = q.shape[1], k.shape[1]
        bq = 128 if sq % 128 == 0 else sq
        bk = 512 if skv % 512 == 0 else (128 if skv % 128 == 0 else skv)
        return flash_attention(
            q, k, v, causal=causal, window=window, block_q=bq, block_k=bk,
            q_offset=q_offset, interpret=(backend == "pallas_interpret"))
    if backend == "xla":
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_chunk=q_chunk, q_offset=q_offset)
    from .ref import attention_ref
    return attention_ref(q, k, v, causal=causal, window=window,
                         q_offset=q_offset)
