"""Pure-jnp oracle for flash attention: naive full-score attention.

Materializes the (Sq, Skv) score matrix — O(S^2) memory, only usable at test
scale, which is exactly its job: the Pallas kernel and the chunked XLA path
are both validated against this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """q: (B,Sq,H,dh); k,v: (B,Skv,KVH,dh) -> (B,Sq,H,dh)."""
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg * dh ** -0.5, kf)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)
