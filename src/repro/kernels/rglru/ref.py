"""Pure-jnp oracle for the RG-LRU diagonal linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(x, log_a, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + x_t, scanned over axis 1.

    x, log_a: (B, S, D) fp32; h0: (B, D) initial state.  Returns (B, S, D).
    """
    b, s, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), x.dtype)

    def step(h, xs):
        xt, lat = xs
        h = jnp.exp(lat) * h + xt
        return h, h

    _, hs = jax.lax.scan(step, h0, (x.swapaxes(0, 1), log_a.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
