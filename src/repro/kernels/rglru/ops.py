"""Dispatch wrapper for the RG-LRU recurrence.

TPU: single-pass Pallas kernel, chunked over the sequence so each tile fits
VMEM (state is carried between chunks through h0).  Elsewhere: XLA
``associative_scan`` (log-depth) — also the gradient path (the Pallas kernel
is forward-only; models call this op inside ``jax.checkpoint`` regions or
serving paths).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def rglru_scan(x, log_a, h0=None, *, force: str = "", seq_chunk: int = 4096):
    """h_t = exp(log_a_t)*h_{t-1} + x_t over axis 1.  (B,S,D) -> (B,S,D)."""
    backend = force or ("pallas" if _on_tpu() else "xla")
    if backend in ("pallas", "pallas_interpret"):
        from .kernel import rglru_pallas
        b, s, d = x.shape
        interp = backend == "pallas_interpret"
        if s <= seq_chunk:
            return rglru_pallas(x, log_a, h0, interpret=interp)
        assert s % seq_chunk == 0
        outs = []
        h = h0
        for i in range(s // seq_chunk):
            sl = slice(i * seq_chunk, (i + 1) * seq_chunk)
            o = rglru_pallas(x[:, sl], log_a[:, sl], h, interpret=interp)
            h = o[:, -1]
            outs.append(o)
        return jnp.concatenate(outs, axis=1)
    if backend == "xla":
        def combine(c1, c2):
            la1, x1 = c1
            la2, x2 = c2
            return la1 + la2, jnp.exp(la2) * x1 + x2
        xx = x if h0 is None else x.at[:, 0].add(
            jnp.exp(log_a[:, 0]) * h0)
        _, h = jax.lax.associative_scan(combine, (log_a, xx), axis=1)
        return h
    from .ref import rglru_ref
    return rglru_ref(x, log_a, h0)
