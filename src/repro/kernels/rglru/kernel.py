"""RG-LRU linear recurrence as a Pallas TPU kernel.

The recurrence ``h_t = a_t * h_{t-1} + x_t`` is elementwise over the feature
dim and sequential over time — a *memory-bound* op (2 loads + 1 store per
element, trivial FLOPs).  The XLA associative_scan evaluates it in log2(S)
full passes over HBM (~15x traffic at S=32k); this kernel makes ONE pass:

Grid: ``(B, D // block_d)`` — independent (batch, feature-block) cells.
BlockSpecs: x, log_a, out: (1, S, block_d) VMEM tiles; the time loop is a
``fori_loop`` over rows of the resident tile, carrying ``h`` in VREGs.

block_d = 128 (lane width); S x block_d x 4B x 3 tiles must fit VMEM, so S
is chunked by the ops.py wrapper at 4096 rows (3 x 2 MiB working set).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(x_ref, la_ref, h0_ref, o_ref, *, seq_len: int):
    h = h0_ref[0, :]                                     # (block_d,)

    def step(t, h):
        ht = jnp.exp(la_ref[0, t, :]) * h + x_ref[0, t, :]
        o_ref[0, t, :] = ht
        return ht

    jax.lax.fori_loop(0, seq_len, step, h)


@functools.partial(jax.jit,
                   static_argnames=("block_d", "interpret"))
def rglru_pallas(x, log_a, h0=None, *, block_d: int = 128,
                 interpret: bool = False):
    """x, log_a: (B, S, D) fp32; h0: (B, D).  One-pass recurrence."""
    b, s, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), x.dtype)
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)

    kernel = functools.partial(_rglru_kernel, seq_len=s)
    return pl.pallas_call(
        kernel,
        grid=(b, d // block_d),
        in_specs=[
            pl.BlockSpec((1, s, block_d), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, s, block_d), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, block_d), lambda bi, di: (bi, di)),
        ],
        out_specs=pl.BlockSpec((1, s, block_d), lambda bi, di: (bi, 0, di)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        interpret=interpret,
    )(x, log_a, h0)
