# Pallas TPU kernels for the framework's compute hot-spots (the paper itself
# contributes monitoring infrastructure, not kernels — these cover the model
# substrate's roofline-dominant ops; see DESIGN.md §6).
#
# Each kernel package: <name>/kernel.py (pl.pallas_call + BlockSpec),
# <name>/ops.py (jit'd dispatch wrapper w/ CPU fallback), <name>/ref.py
# (pure-jnp oracle swept against the kernel in interpret mode).
