from .synthetic import (SyntheticLMData, SyntheticImageData, SyntheticSeq2Seq,
                        host_transfer_log)

__all__ = ["SyntheticLMData", "SyntheticImageData", "SyntheticSeq2Seq",
           "host_transfer_log"]
