"""Deterministic synthetic data pipelines.

Production properties the framework needs (and tests assert):

* **deterministic resume** — batch at step ``t`` is a pure function of
  ``(seed, step, host)``; restart from a checkpoint replays identical data
  with no loader state to save;
* **host sharding** — each host materializes only its slice of the global
  batch (here: single host = full slice);
* **host-transfer accounting** — every ``device_put`` is logged as a
  :class:`~repro.core.events.HostTransfer`, which fills the (0, j) host
  row/column of the paper's communication matrix (Fig. 2's host entries).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import HostTransfer

_TRANSFERS: list[HostTransfer] = []


def host_transfer_log() -> list[HostTransfer]:
    return _TRANSFERS


def _log_put(tree, label: str):
    for leaf in jax.tree.leaves(tree):
        _TRANSFERS.append(HostTransfer(
            direction="h2d", device=0,
            nbytes=int(np.prod(leaf.shape)) * leaf.dtype.itemsize,
            label=label))


@dataclasses.dataclass
class SyntheticLMData:
    """Zipf-ish token stream: tokens[t] depends only on (seed, step, host)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        # zipf-like marginal so loss curves are non-trivial
        u = rng.random((self.host_batch, self.seq_len + 1))
        toks = np.minimum(
            (self.vocab_size * u ** 2.2).astype(np.int64),
            self.vocab_size - 1).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        _log_put(batch, f"lm_batch[{step}]")
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class SyntheticImageData:
    """64x64 image classification batches (the paper's ResNet-18 setting)."""

    num_classes: int
    global_batch: int
    image_size: int = 64
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        labels = rng.integers(0, self.num_classes, self.host_batch)
        # class-conditioned gaussians => learnable signal
        base = np.linspace(-1, 1, self.num_classes)[labels]
        imgs = (rng.standard_normal(
            (self.host_batch, self.image_size, self.image_size, 3)) * 0.35
            + base[:, None, None, None]).astype(np.float32)
        batch = {"images": jnp.asarray(imgs),
                 "labels": jnp.asarray(labels.astype(np.int32))}
        _log_put(batch, f"img_batch[{step}]")
        return batch


@dataclasses.dataclass
class SyntheticSeq2Seq:
    """Copy-reverse translation task for the GNMT app."""

    vocab_size: int
    src_len: int
    tgt_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        src = rng.integers(2, self.vocab_size,
                           (self.host_batch, self.src_len)).astype(np.int32)
        # target = reversed source (teacher forcing, BOS=1)
        tgt_full = src[:, ::-1][:, :self.tgt_len]
        tgt_in = np.concatenate(
            [np.ones((self.host_batch, 1), np.int32), tgt_full[:, :-1]], 1)
        batch = {"src": jnp.asarray(src), "tgt": jnp.asarray(tgt_in),
                 "labels": jnp.asarray(tgt_full)}
        _log_put(batch, f"mt_batch[{step}]")
        return batch
