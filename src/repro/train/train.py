"""Training loop core: jit-compiled train step with GSPMD parallelism.

Collectives here are *compiler-scheduled* (FSDP all-gather/reduce-scatter, TP
psum, DP all-reduce) — the monitor's traced-vs-compiled diff shows zero traced
calls and the full compiled schedule, the TPU-native inversion of the paper's
NCCL view (DESIGN.md §2).

Features: microbatch gradient accumulation (collectives hoisted out of the
scan), bf16 gradient communication (halves FSDP/DP wire bytes; §Perf),
donated state, deterministic metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import OptConfig, apply_updates, init_opt_state, opt_state_axes
from repro.parallel import Sharder


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "dots"                  # none | dots | full
    grad_dtype: str = "float32"          # "bfloat16" halves grad-sync bytes
    accum_dtype: str = "float32"         # bf16 halves the accumulation buffer
    seed: int = 0


TrainState = dict  # {"params": pytree, "opt": pytree, "step": int32}


def init_train_state(model, opt_cfg: OptConfig, rng) -> TrainState:
    params = model.init(rng)
    return {"params": params, "opt": init_opt_state(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def train_state_shapes(model, opt_cfg: OptConfig) -> TrainState:
    params = model.shapes()
    opt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_shardings(model, opt_cfg: OptConfig, shd: Sharder):
    p_axes = model.axes()
    p_shapes = model.shapes()
    o_axes = opt_state_axes(p_axes, p_shapes, opt_cfg)
    o_shapes = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), p_shapes)
    return {
        "params": shd.tree_shardings(p_shapes, p_axes),
        "opt": shd.tree_shardings(o_shapes, o_axes),
        "step": shd.replicated(),
    }


def batch_shardings(batch_shapes, shd: Sharder):
    def leaf(s):
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        if len(s.shape) >= 2:
            axes = ("batch", "seq") + (None,) * (len(s.shape) - 2)
        return shd.named(s.shape, axes)
    return jax.tree.map(leaf, batch_shapes)


def make_train_step(model, opt_cfg: OptConfig, train_cfg: TrainConfig,
                    shd: Sharder) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        if train_cfg.grad_dtype == "bfloat16":
            # cast to bf16 AND pin to the param sharding: the constraint
            # keeps the convert on the sharded side so FSDP all-gathers move
            # bf16, not the f32 master (halves weight-gather wire bytes)
            p_axes = model.axes()
            leaves, treedef = jax.tree.flatten(params)
            axes = treedef.flatten_up_to(p_axes)
            leaves = [
                shd.constraint(p.astype(jnp.bfloat16), ax)
                if p.dtype == jnp.float32 and p.ndim > 1 else p
                for p, ax in zip(leaves, axes)]
            params = jax.tree.unflatten(treedef, leaves)
        return model.loss_fn(params, batch, shd, remat=train_cfg.remat)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        params = state["params"]
        a = train_cfg.microbatches
        if a <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]),
                batch)

            # accumulator pinned to the parameter sharding: each microbatch
            # reduce-scatters its gradient (ZeRO); without the constraint
            # GSPMD may keep the carry replicated and emit full all-reduces
            # per microbatch (llama4 §Perf iteration: 1.3 PiB/step saved)
            p_axes = model.axes()

            def pin(tree):
                shapes, treedef = jax.tree.flatten(tree)
                axes = treedef.flatten_up_to(p_axes)
                return jax.tree.unflatten(treedef, [
                    shd.constraint(x, ax) for x, ax in zip(shapes, axes)])

            def body(carry, b):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(params, b)
                # pin the microbatch grad BEFORE the add: the partitioner
                # then reduces the grad dot directly into the shard
                # (reduce-scatter) instead of AR-ing a full copy and
                # re-gathering the sharded accumulator
                g = pin(g)
                g_acc = jax.tree.map(
                    lambda x, y: x + y.astype(x.dtype), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.dtype(train_cfg.accum_dtype)),
                params))
            (grads, loss), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / a, grads)
            loss = loss / a
            metrics = {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

        new_params, new_opt, stats = apply_updates(
            params, grads, state["opt"], opt_cfg, state["step"])
        metrics = dict(metrics, loss=loss, **stats)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step


def jit_train_step(model, opt_cfg: OptConfig, train_cfg: TrainConfig,
                   shd: Sharder, donate: bool = True):
    """jit'd train step with explicit state shardings (the dry-run target)."""
    step = make_train_step(model, opt_cfg, train_cfg, shd)
    state_sh = train_state_shardings(model, opt_cfg, shd)
    kw: dict[str, Any] = dict(
        in_shardings=(state_sh, None), out_shardings=(state_sh, None))
    if donate:
        kw["donate_argnums"] = (0,)
    return jax.jit(step, **kw), state_sh
