from .train import TrainConfig, TrainState, make_train_step, init_train_state, train_state_shardings
from . import ddp

__all__ = ["TrainConfig", "TrainState", "make_train_step", "init_train_state",
           "train_state_shardings", "ddp"]
