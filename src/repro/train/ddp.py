"""Explicit DDP gradient synchronization — the paper's PyTorch scenario.

PyTorch-DDP issues one ncclAllReduce per gradient bucket (Table 3 of the
paper; gradient bucketing is [16] Li et al.).  This module reproduces that
communication pattern with *application-issued* collectives (``jax.lax.psum``
inside ``shard_map``) in three flavours the benchmarks sweep:

* ``per_param`` — one AllReduce per gradient tensor (naive DDP),
* ``bucketed``  — gradients flattened/concatenated into ~``bucket_mb`` MiB
  buckets, one AllReduce per bucket (PyTorch default, 25 MiB),
* optional bf16 compression with fp32 error-feedback on either.

Because these collectives are traced by the application, the interceptor
(LD_PRELOAD analogue) sees them — this is the path that exercises the
paper's original workflow end-to-end.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------
def bucket_plan(params, bucket_mb: float = 25.0):
    """Greedy assignment of leaves to ~bucket_mb MiB buckets (by fp32 size)."""
    leaves, treedef = jax.tree.flatten(params)
    limit = bucket_mb * 1024 * 1024
    buckets, cur, cur_bytes = [], [], 0.0
    for i, leaf in enumerate(leaves):
        nbytes = float(np.prod(leaf.shape)) * 4
        if cur and cur_bytes + nbytes > limit:
            buckets.append(cur)
            cur, cur_bytes = [], 0.0
        cur.append(i)
        cur_bytes += nbytes
    if cur:
        buckets.append(cur)
    return buckets, treedef


def allreduce_bucketed(grads, axis_name: str, bucket_mb: float = 25.0,
                       compress: bool = False, error_feedback=None):
    """AllReduce grads in buckets.  Returns (synced grads, new error_feedback).

    ``compress=True`` casts each bucket to bf16 for the wire (half bytes) and
    keeps the fp32 quantization error in ``error_feedback`` (same structure
    as grads) to be re-added next step — classic EF compression.
    """
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = (treedef.flatten_up_to(error_feedback)
                 if error_feedback is not None else [None] * len(leaves))
    buckets, _ = bucket_plan(grads, bucket_mb)
    out = [None] * len(leaves)
    new_ef = [None] * len(leaves)
    for idx in buckets:
        flat = []
        for i in idx:
            g = leaves[i].astype(jnp.float32)
            if ef_leaves[i] is not None:
                g = g + ef_leaves[i]
            flat.append(g.reshape(-1))
        buf = jnp.concatenate(flat) if len(flat) > 1 else flat[0]
        if compress:
            wire = buf.astype(jnp.bfloat16)
            err = buf - wire.astype(jnp.float32)
            buf = jax.lax.pmean(wire, axis_name).astype(jnp.float32)
        else:
            err = None
            buf = jax.lax.pmean(buf, axis_name)
        off = 0
        for i in idx:
            n = int(np.prod(leaves[i].shape))
            out[i] = buf[off:off + n].reshape(leaves[i].shape)
            if err is not None:
                new_ef[i] = err[off:off + n].reshape(leaves[i].shape)
            off += n
    grads_out = jax.tree.unflatten(treedef, out)
    ef_out = (jax.tree.unflatten(treedef, new_ef)
              if compress and error_feedback is not None else error_feedback)
    return grads_out, ef_out


def allreduce_per_param(grads, axis_name: str):
    """One AllReduce per tensor (naive DDP; paper's D x N counting)."""
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)


# ---------------------------------------------------------------------------
# a complete DDP train step (shard_map over the data axis)
# ---------------------------------------------------------------------------
def make_ddp_train_step(loss_fn: Callable, mesh, *, axis_name: str = "data",
                        mode: str = "bucketed", bucket_mb: float = 25.0,
                        compress: bool = False, lr: float = 1e-3):
    """loss_fn(params, batch) -> (loss, metrics).  Params replicated; batch
    sharded over ``axis_name``.  SGD update inline (the paper's apps)."""

    def step(params, ef, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if mode == "per_param":
            grads = allreduce_per_param(grads, axis_name)
        else:
            grads, ef = allreduce_bucketed(grads, axis_name, bucket_mb,
                                           compress=compress,
                                           error_feedback=ef)
        loss = jax.lax.pmean(loss, axis_name)
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, ef, loss

    in_specs = (P(), P(), P(axis_name))
    out_specs = (P(), P(), P())
    from repro.compat import shard_map
    mapped = shard_map(step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return jax.jit(mapped)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
